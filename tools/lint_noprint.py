"""Forbid bare ``print(`` in ``src/repro/`` (``make lint-noprint``).

Runtime output goes through the observability layer (``repro.obs``):
structured events into sinks, with ``ConsoleSink`` as the one place
that actually writes to a terminal.  A stray ``print`` in library code
bypasses every sink (tests can't capture it, JSONL logs lose it), so
this lint keeps the count pinned at the explicit allowlist below.

Token-based (``tokenize``), not textual: comments, docstrings, and
strings mentioning print are fine; only a ``print`` NAME token
immediately followed by ``(`` counts.  A line may opt out with a
``# noqa: lint-noprint`` comment (used by ConsoleSink itself).

  python tools/lint_noprint.py            # lint src/repro
  python tools/lint_noprint.py PATH...    # lint specific files/trees
"""
from __future__ import annotations

import io
import os
import sys
import tokenize
from typing import Iterator, List, Tuple

# files whose prints are sanctioned terminal UIs, not library output:
# the launch CLIs talk to an operator, and ConsoleSink IS the console
ALLOWLIST = (
    os.path.join("src", "repro", "obs", "sinks.py"),
    os.path.join("src", "repro", "launch", "dryrun.py"),
    os.path.join("src", "repro", "launch", "serve.py"),
    os.path.join("src", "repro", "launch", "train.py"),
)
NOQA = "noqa: lint-noprint"


def iter_py_files(root: str) -> Iterator[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, _, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def find_prints(path: str) -> List[Tuple[int, str]]:
    """(line number, line text) for every bare ``print(`` call site."""
    with open(path, "rb") as f:
        src = f.read()
    lines = src.decode("utf-8").splitlines()
    hits: List[Tuple[int, str]] = []
    toks = list(tokenize.tokenize(io.BytesIO(src).readline))
    for tok, nxt in zip(toks, toks[1:]):
        if (tok.type == tokenize.NAME and tok.string == "print"
                and nxt.type == tokenize.OP and nxt.string == "("):
            line = lines[tok.start[0] - 1]
            if NOQA not in line:
                hits.append((tok.start[0], line.strip()))
    return hits


def main(argv: List[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    roots = argv or [os.path.join(repo, "src", "repro")]
    allow = {os.path.join(repo, p) for p in ALLOWLIST}
    bad = 0
    for root in roots:
        for path in iter_py_files(root):
            if os.path.abspath(path) in allow:
                continue
            for lineno, line in find_prints(path):
                rel = os.path.relpath(path, repo)
                print(f"{rel}:{lineno}: bare print() — emit through "
                      f"repro.obs instead: {line}")
                bad += 1
    if bad:
        print(f"lint-noprint: {bad} violation(s)")
        return 1
    print("lint-noprint: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
