"""Quickstart: FedCore vs the baselines on the paper's Synthetic(1,1)
benchmark — the 60-second tour of the whole system.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.server import FLConfig, run_federated, summarize
from repro.fed.simulator import make_client_specs
from repro.fed.strategies import FedAvg, FedAvgDS, FedCore, FedProx, LocalTrainer
from repro.models.small import LogisticRegression


def main():
    # 1. a federated world: 10 clients, power-law data, heterogeneous compute
    clients = synthetic_dataset(alpha=1.0, beta=1.0, n_clients=10,
                                mean_samples=120, std_samples=100, seed=0)
    train, test = train_test_split_clients(clients)
    specs = make_client_specs([len(d["y"]) for d in train],
                              np.random.default_rng(0))
    model = LogisticRegression()
    cfg = FLConfig(rounds=10, clients_per_round=5, epochs=5, batch_size=8,
                   lr=0.05, straggler_pct=30.0, eval_every=2)

    # 2. run all four strategies under the same straggler deadline
    print(f"{'strategy':10s} {'final acc':>10s} {'t/round (norm)':>15s} "
          f"{'meets tau'}")
    for name, make in {
        "fedavg": lambda: FedAvg(LocalTrainer(model, cfg.lr,
                                              cfg.batch_size)),
        "fedavg_ds": lambda: FedAvgDS(LocalTrainer(model, cfg.lr,
                                                   cfg.batch_size)),
        "fedprox": lambda: FedProx(LocalTrainer(model, cfg.lr,
                                                cfg.batch_size,
                                                prox_mu=0.1)),
        "fedcore": lambda: FedCore(LocalTrainer(model, cfg.lr,
                                                cfg.batch_size)),
    }.items():
        out = run_federated(model, train, specs, make(), cfg, test)
        s = summarize(out["history"], out["deadline"])
        meets = "yes" if s["max_round_time_normalized"] <= 1.001 else "NO"
        print(f"{name:10s} {s['final_test_acc']:10.4f} "
              f"{s['mean_round_time_normalized']:15.3f} {meets:>9s}")

    print("\nFedCore: deadline met AND accuracy preserved — the coresets "
          "let stragglers contribute full-depth updates on time.")


if __name__ == "__main__":
    main()
