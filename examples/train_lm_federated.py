"""End-to-end driver (deliverable b): train a ~100M-parameter decoder LM —
centralized for a few hundred steps, then the same model federated across
silos with FedCore coreset selection for stragglers.

Full run (a few hundred steps of the 100M preset; use on real hardware):
  PYTHONPATH=src python examples/train_lm_federated.py --preset 100m \
      --steps 300

CI scale (runs in ~2 min on 1 CPU core):
  PYTHONPATH=src python examples/train_lm_federated.py --preset tiny \
      --steps 20
"""
import argparse

from repro.launch.train import PRESETS, train_centralized, train_fedcore_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print("== phase 1: centralized pretraining ==")
    out = train_centralized(cfg, args.steps, args.batch, args.seq, 3e-4,
                            ckpt_dir=None, log_every=max(1, args.steps // 5),
                            seed=0)
    print(f"loss {out['initial_loss']:.4f} -> {out['final_loss']:.4f}")

    print("== phase 2: federated fine-tuning with FedCore coresets ==")
    train_fedcore_lm(cfg, rounds=2, steps_per_epoch=4, silos=3,
                     batch=args.batch, seq=args.seq, lr=1e-3,
                     straggler_pct=34.0, seed=0)


if __name__ == "__main__":
    main()
