"""FedCore on the asynchronous event-driven runtime.

Runs FedCore through the async engine with staleness-aware aggregation
and a time-varying capability trace, next to the classic synchronous
round loop, and prints the async telemetry (client utilization,
staleness histogram, makespan).

  PYTHONPATH=src python examples/fedcore_async.py --updates 40
"""
import argparse

import numpy as np

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.aggregators import AGGREGATORS
from repro.fed.events import AsyncFLConfig, run_federated_async
from repro.fed.server import FLConfig, run_federated, summarize
from repro.fed.simulator import TraceConfig, make_client_specs
from repro.fed.strategies import FedCore, LocalTrainer
from repro.models.small import LogisticRegression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--updates", type=int, default=40,
                    help="async server updates (versions) to apply")
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--stragglers", type=float, default=30.0)
    ap.add_argument("--aggregator", default="delayed_grad",
                    choices=[k for k in AGGREGATORS if k != "sync_mean"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    clients = synthetic_dataset(0.5, 0.5, n_clients=args.clients,
                                mean_samples=100, std_samples=150,
                                seed=args.seed)
    train, test = train_test_split_clients(clients, test_frac=0.3)
    specs = make_client_specs([len(d["y"]) for d in train],
                              np.random.default_rng(args.seed))
    model = LogisticRegression()
    lr, batch = 0.05, 8

    # synchronous reference: same client-update budget
    rounds = max(1, args.updates // args.concurrency)
    sync_cfg = FLConfig(rounds=rounds, clients_per_round=args.concurrency,
                        epochs=args.epochs, batch_size=batch, lr=lr,
                        straggler_pct=args.stragglers, eval_every=1,
                        seed=args.seed)
    out = run_federated(model, train, specs,
                        FedCore(LocalTrainer(model, lr, batch)), sync_cfg,
                        test, verbose=True)
    s = summarize(out["history"], out["deadline"])
    sync_time = sum(r.sim_round_time for r in out["history"])
    print(f"== fedcore-sync: acc {s['final_test_acc']:.4f} "
          f"virtual time {sync_time:.1f}s\n")

    async_cfg = AsyncFLConfig(
        max_updates=args.updates, concurrency=args.concurrency,
        epochs=args.epochs, batch_size=batch, lr=lr,
        straggler_pct=args.stragglers,
        record_every=max(1, args.concurrency), eval_every=1,
        seed=args.seed, trace=TraceConfig(seed=args.seed))
    aout = run_federated_async(model, train, specs,
                               FedCore(LocalTrainer(model, lr, batch)),
                               async_cfg,
                               aggregator=AGGREGATORS[args.aggregator](),
                               test_data=test, verbose=True)
    t = aout["telemetry"]
    sa = summarize(aout["history"], aout["deadline"])
    speedup = sync_time / t["makespan"] if t["makespan"] > 0 else float("nan")
    print(f"== fedcore-async/{aout['aggregator']}: "
          f"acc {sa['final_test_acc']:.4f} makespan {t['makespan']:.1f}s "
          f"({speedup:.2f}x vs sync)")
    print(f"   client utilization {t['client_utilization']:.2%} "
          f"(active clients {t['active_client_utilization']:.2%})")
    print(f"   updates {t['n_updates_applied']} over "
          f"{t['n_dispatches']} dispatches, {t['n_dropped']} dropped")
    print(f"   staleness: mean {t['mean_staleness']:.2f}, "
          f"hist {t['staleness_hist'].tolist()}")


if __name__ == "__main__":
    main()
