"""Paper benchmark #1: pseudo-MNIST CNN federated training with stragglers.

Reduced scale by default (~40 clients); pass --scale paper for the
published 1000-client setting (Table 1) on capable hardware.

  PYTHONPATH=src python examples/fedcore_mnist.py --rounds 8
"""
import argparse

import numpy as np

from repro.data.mnist_like import mnist_like_dataset
from repro.data.partition import train_test_split_clients
from repro.fed.server import FLConfig, run_federated, summarize
from repro.fed.simulator import make_client_specs
from repro.fed.strategies import FedAvgDS, FedCore, LocalTrainer
from repro.models.small import SmallCNN


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "paper"])
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--stragglers", type=float, default=30.0)
    args = ap.parse_args()

    n_clients = 1000 if args.scale == "paper" else 24
    rounds = 100 if args.scale == "paper" else args.rounds
    k = 100 if args.scale == "paper" else 6

    clients = mnist_like_dataset(n_clients=n_clients, mean_samples=40,
                                 std_samples=30, seed=0)
    train, test = train_test_split_clients(clients)
    specs = make_client_specs([len(d["y"]) for d in train],
                              np.random.default_rng(0))
    model = SmallCNN()
    cfg = FLConfig(rounds=rounds, clients_per_round=k, epochs=5,
                   batch_size=8, lr=0.03, straggler_pct=args.stragglers,
                   eval_every=max(1, rounds // 4))

    for name, strat in {
        "fedavg_ds": FedAvgDS(LocalTrainer(model, cfg.lr, cfg.batch_size)),
        "fedcore": FedCore(LocalTrainer(model, cfg.lr, cfg.batch_size)),
    }.items():
        out = run_federated(model, train, specs, strat, cfg, test,
                            verbose=True)
        s = summarize(out["history"], out["deadline"])
        print(f"== {name}: acc {s['final_test_acc']:.4f} "
              f"t/round {s['mean_round_time_normalized']:.3f}")


if __name__ == "__main__":
    main()
