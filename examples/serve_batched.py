"""Batched serving example (deliverable b): KV-cache decode with sampling
across architecture families — dense (GQA ring-buffer cache), hybrid
(Mamba2 state + shared-attention cache) and xLSTM (matrix-memory state).

  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models.model import Model


def main():
    for arch in ("yi-9b", "zamba2-1.2b", "xlstm-125m"):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                     cfg.vocab_size)
        out = generate(model, params, prompts, gen=12, temperature=0.8)
        assert out.shape == (2, 20)
        assert not bool(jnp.isnan(out).any())
        print(f"{arch:14s} (smoke, family={cfg.family:7s}): "
              f"generated {out.shape[1] - 8} tokens/seq ok")


if __name__ == "__main__":
    main()
