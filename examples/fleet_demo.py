"""Fleet-scale FedCore demo: adaptive participation over a 512-client
device-class mixture, executed by the batched engine.

Shows the three fleet pieces working together:
  * a named scenario ("device_classes") materializes specs + a capability
    trace from the registry;
  * an ``AdaptiveParticipation`` scheduler starts with the 16 fastest
    clients and doubles the cohort whenever train loss plateaus, while
    conditioning each client's coreset budget on its *observed* (EWMA)
    capability;
  * ``run_fleet`` executes every round's whole cohort as a few vmapped
    XLA programs — no per-client Python loop.

  PYTHONPATH=src python examples/fleet_demo.py
  # mesh-sharded execution over N virtual CPU devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/fleet_demo.py --engine sharded
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.fleet import (AdaptiveParticipation, FleetConfig,
                             ParticipationConfig, build_scenario, run_fleet)
from repro.models.small import LogisticRegression


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="batched",
                    choices=("batched", "loop", "sharded"),
                    help="fleet execution model; 'sharded' runs cohort "
                         "groups data-parallel over all devices (falls "
                         "back to batched on a one-device host)")
    args = ap.parse_args()
    n_clients = 512
    clients = synthetic_dataset(0.5, 0.5, n_clients=n_clients,
                                mean_samples=48.0, std_samples=32.0, seed=0)
    train, test = train_test_split_clients(clients, test_frac=0.2)
    sizes = [len(d["y"]) for d in train]
    specs, trace = build_scenario("device_classes", sizes, seed=0)

    model = LogisticRegression()
    scheduler = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=16, growth_factor=2.0, plateau_tol=0.02))
    cfg = FleetConfig(epochs=2, batch_size=32, lr=0.05, seed=0)

    out = run_fleet(model, train, specs, cfg, rounds=8,
                    scheduler=scheduler, trace=trace, test_data=test,
                    engine=args.engine, verbose=True)

    print(f"\nengine: {out['engine']} (ran {out['engine_mode']} on "
          f"{out['n_devices']} device(s))")
    print("cohort trajectory:", out["cohort_sizes"])
    print("scheduler:", scheduler.summary())
    final = out["history"][-1]
    print(f"final test acc {final.test_acc:.4f} "
          f"(deadline {out['deadline']:.1f}s)")


if __name__ == "__main__":
    main()
