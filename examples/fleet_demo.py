"""Fleet-scale FedCore demo: adaptive participation over a device-class
mixture, executed by the batched engine on any registered FleetWorkload
(default: a SmallCNN image fleet).

Shows the four fleet pieces working together:
  * a ``FleetWorkload`` from the registry supplies the model, the data
    schema, and the federated dataset builder (``--workload`` picks
    mlp / cnn / charlm / xlstm — model diversity is one axis);
  * a named scenario ("device_classes") materializes specs + a capability
    trace from the registry;
  * an ``AdaptiveParticipation`` scheduler starts with the 16 fastest
    clients and doubles the cohort whenever train loss plateaus, while
    conditioning each client's coreset budget on its *observed* (EWMA)
    capability;
  * ``run_fleet`` executes every round's whole cohort as a few vmapped
    XLA programs — no per-client Python loop.

With ``--runtime async_fleet`` the same fleet runs through the
event-driven engine instead: no barrier rounds — completions accumulate
in a server-side buffer and every K of them are micro-batched into fused
cohort-group programs, merged under a staleness-aware rule (FedBuff by
default; ``--aggregator fedasync`` / ``delayed_grad`` switch the rule).

  PYTHONPATH=src python examples/fleet_demo.py                 # CNN fleet
  PYTHONPATH=src python examples/fleet_demo.py --workload charlm
  PYTHONPATH=src python examples/fleet_demo.py --runtime async_fleet
  # mesh-sharded execution over N virtual CPU devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/fleet_demo.py --engine sharded
"""
from __future__ import annotations

import argparse

from repro.data.partition import train_test_split_clients
from repro.fed.fleet import (AdaptiveParticipation, AsyncFleetConfig,
                             FleetConfig, ParticipationConfig,
                             build_scenario, client_sizes, get_workload,
                             run_async_fleet, run_fleet)

# fleet sizes per workload, scaled so the demo stays interactive on CPU
N_CLIENTS = {"mlp": 512, "cnn": 256, "charlm": 128, "xlstm": 128}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="batched",
                    choices=("batched", "loop", "sharded"),
                    help="fleet execution model; 'sharded' runs cohort "
                         "groups data-parallel over all devices (falls "
                         "back to batched on a one-device host)")
    ap.add_argument("--workload", default="cnn",
                    choices=tuple(sorted(N_CLIENTS)),
                    help="FleetWorkload to run (model + data schema + "
                         "dataset builder from the registry)")
    ap.add_argument("--runtime", default="fleet",
                    choices=("fleet", "async_fleet"),
                    help="barrier-synchronous rounds (run_fleet) or the "
                         "event-driven buffered engine (run_async_fleet)")
    ap.add_argument("--aggregator", default="fedbuff",
                    choices=("fedbuff", "fedasync", "delayed_grad"),
                    help="async_fleet merge rule (ignored for --runtime "
                         "fleet)")
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    workload = get_workload(args.workload)
    n_clients = N_CLIENTS[args.workload]
    clients = workload.make_clients(n_clients=n_clients, seed=0)
    workload.validate_clients(clients)
    train, test = train_test_split_clients(clients, test_frac=0.2)
    specs, trace = build_scenario("device_classes", client_sizes(train),
                                  seed=0)

    scheduler = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=16, growth_factor=2.0, plateau_tol=0.02))

    print(f"workload: {workload.name} — {workload.description}")
    if args.runtime == "async_fleet":
        cfg = AsyncFleetConfig(max_updates=args.rounds, buffer_k=16,
                               concurrency=32, epochs=2, batch_size=32,
                               lr=0.05, seed=0, trace=trace)
        out = run_async_fleet(workload, train, specs, cfg,
                              aggregator=args.aggregator,
                              scheduler=scheduler, test_data=test,
                              engine=args.engine, verbose=True)
        tel = out["telemetry"]
        print(f"\nengine: {out['engine']} (ran {out['engine_mode']} on "
              f"{out['n_devices']} device(s)), merge rule "
              f"{out['aggregator']}")
        print(f"{tel['n_merged_clients']} client updates merged through "
              f"{tel['n_group_dispatches']} jitted group programs in "
              f"{out['applied']} flushes; mean staleness "
              f"{tel['mean_staleness']:.2f}")
    else:
        cfg = FleetConfig(epochs=2, batch_size=32, lr=0.05, seed=0)
        out = run_fleet(workload, train, specs, cfg, rounds=args.rounds,
                        scheduler=scheduler, trace=trace, test_data=test,
                        engine=args.engine, verbose=True)
        print(f"\nengine: {out['engine']} (ran {out['engine_mode']} on "
              f"{out['n_devices']} device(s))")
        print("cohort trajectory:", out["cohort_sizes"])
    print("scheduler:", scheduler.summary())
    final = out["history"][-1]
    print(f"final test acc {final.test_acc:.4f} "
          f"(deadline {out['deadline']:.1f}s)")


if __name__ == "__main__":
    main()
