PY ?= python

.PHONY: test ci bench-async bench-fleet bench-fleet-smoke \
	bench-fleet-sharded bench-fleet-async bench-selection \
	bench-fleet-workloads bench-fleet-translm bench-cost bench-faults \
	report lint-noprint

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# CI entry point: CPU-pinned tier-1 suite + the fleet + selection smokes
ci:
	$(MAKE) lint-noprint
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) -m pytest -x -q
	$(MAKE) bench-fleet-smoke
	$(MAKE) bench-selection

# telemetry walkthrough: produce a small fleet JSONL run log
# (runs/obs_demo.jsonl) and render the phase-timeline / straggler /
# utilization report from it (benchmarks/report.py <log> reports on any
# existing repro.obs JSONL log instead)
report:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/report.py --demo

# keep-green gate: no new bare print() in src/repro — runtime output
# goes through repro.obs sinks (see tools/lint_noprint.py's allowlist)
lint-noprint:
	$(PY) tools/lint_noprint.py

bench-async:
	PYTHONPATH=src $(PY) benchmarks/async_vs_sync.py --mode smoke

# full fleet sweep: 1024-client engine benchmark + scenario matrix
bench-fleet:
	PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py

# CI-sized sweep; --min-speedup 3 is the keep-green regression floor
# (the tracked BENCH_fleet.json reports the real number, >= 5x locally);
# the selection section runs in its own bench-selection target
bench-fleet-smoke:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --min-speedup 3 --skip-selection

# selection-phase smoke: the fused single-dispatch Δ-sweep fast path vs
# the pre-fusion 3-dispatch chain at 1024 clients, plus the Pallas-kernel
# on/off A-B.  --min-selection-speedup 1 is the keep-green no-regression
# floor (the tracked BENCH_fleet.json records the real number, >= 1.5x);
# gates on fused == pre-fusion medoid parity either way.  Also runs the
# distance-free selection-memory A/B (peak RSS at M in {128, 512, 2048},
# fresh subprocess per point): distance-free must complete M=2048 under
# 25% of the stack path's extrapolated O(C·M²) peak and hold >=1x
# throughput at M=128
bench-selection:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-engine --skip-scenarios --skip-workloads \
		--min-selection-speedup 1.0 --selection-memory \
		--min-selection-memory-speedup 1.0

# per-workload fleet rounds (mlp/cnn/charlm/xlstm/translm through the
# batched fleet runtime + loop round-0 parity); recorded in
# BENCH_fleet.json
bench-fleet-workloads:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-engine --skip-scenarios --skip-selection

# translm through the *engine* benchmark: batched-vs-loop parity and the
# keep-green no-regression speedup floor on the transformer-LM workload
# (the conformance matrix covers its per-engine cells; this gates the
# full timed round at fleet scale).  96 clients keeps CI wall time
# small; a separate --out keeps the tracked BENCH_fleet.json's headline
# (mlp) engine section intact.
bench-fleet-translm:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-scenarios --skip-selection --skip-workloads \
		--workload translm --clients 96 --min-speedup 1.0 \
		--max-recording-overhead 25 \
		--out benchmarks/BENCH_fleet_translm.json

# cost-conditioned budget gate: measure every workload's per-sample step
# cost (HLO FLOPs of the jitted local-SGD step, normalized to mlp) and
# run the translm deadline A/B under device_classes — cost-conditioned
# budgets vs the κ-ignorant legacy sample-count planner on identical
# measured durations; keep-green gate is violation-rate(cost) <=
# violation-rate(legacy), recorded in BENCH_fleet.json["cost_model"]
bench-cost:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-engine --skip-scenarios --skip-selection \
		--skip-workloads --cost-model

# fault matrix + Byzantine robustness gate: dropout / churn / sign-flip
# Byzantine profiles crossed with the server aggregation rules
# (weighted_mean / trimmed_mean / median / krum) on the mlp fleet, plus
# the keep-green gate — under 20% sign-flip Byzantine clients at least
# one robust aggregator must beat weighted_mean's final accuracy;
# recorded in BENCH_fleet.json["faults"] with the margin
bench-faults:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-engine --skip-scenarios --skip-selection \
		--skip-workloads --faults

# event-driven async fleet engine: throughput at the reference fleet
# size vs the sync batched round, plus the 100k-client lazy-data scale
# completion point.  --min-async-ratio 0.3 is the keep-green floor (the
# tracked BENCH_fleet.json records the real ratio, >= 0.5x locally);
# the ratio gate reads the sync reference from the tracked file when the
# engine section is skipped
bench-fleet-async:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-engine --skip-scenarios --skip-selection \
		--skip-workloads --async-fleet --min-async-ratio 0.3

# sharded-engine scaling sweep: one subprocess per device count (XLA
# forced host-platform devices on CPU); gates on sharded==batched parity
# and on the mesh never being *slower* than one device; records the
# measured throughput per device count (wall-clock scaling is bounded by
# the host's physical cores — see sharded_scaling.n_cpu_cores; the >=2x
# target needs a >=4-core host or real accelerators)
bench-fleet-sharded:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(PY) benchmarks/fleet_sweep.py \
		--smoke --skip-engine --skip-scenarios --skip-selection \
		--skip-workloads --device-sweep 1,2,4 --min-scaling 1.0
