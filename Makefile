PY ?= python

.PHONY: test bench-async

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-async:
	PYTHONPATH=src $(PY) benchmarks/async_vs_sync.py --mode smoke
