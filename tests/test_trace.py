"""CapabilityTrace/TraceConfig unit tests: seeded determinism, episode
statistics, and mean-1 jitter normalization (previously untested)."""
import numpy as np

from repro.fed.simulator import CapabilityTrace, ClientSpec, TraceConfig

SPEC = ClientSpec(cid=3, m=100, c=2.0)


def _episode_lengths(flags):
    lengths, run = [], 0
    for f in flags:
        if f:
            run += 1
        elif run:
            lengths.append(run)
            run = 0
    if run:
        lengths.append(run)
    return lengths


def test_same_seed_same_trace_across_instances():
    cfg = TraceConfig(jitter_std=0.2, slowdown_prob=0.1, seed=11)
    a, b = CapabilityTrace(cfg), CapabilityTrace(cfg)
    got_a = [(a.capability(SPEC, k), a.jitter(SPEC, k)) for k in range(64)]
    got_b = [(b.capability(SPEC, k), b.jitter(SPEC, k)) for k in range(64)]
    assert got_a == got_b


def test_query_order_does_not_change_trace():
    cfg = TraceConfig(jitter_std=0.2, slowdown_prob=0.2, seed=5)
    fwd, rev = CapabilityTrace(cfg), CapabilityTrace(cfg)
    ks = list(range(32))
    a = {k: (fwd.capability(SPEC, k), fwd.jitter(SPEC, k)) for k in ks}
    b = {k: (rev.capability(SPEC, k), rev.jitter(SPEC, k))
         for k in reversed(ks)}
    assert a == b


def test_different_seeds_and_clients_decorrelate():
    cfg0, cfg1 = TraceConfig(seed=0), TraceConfig(seed=1)
    t0, t1 = CapabilityTrace(cfg0), CapabilityTrace(cfg1)
    seq0 = [t0.jitter(SPEC, k) for k in range(32)]
    seq1 = [t1.jitter(SPEC, k) for k in range(32)]
    assert seq0 != seq1
    other = ClientSpec(cid=4, m=100, c=2.0)
    assert seq0 != [t0.jitter(other, k) for k in range(32)]


def test_slowdown_episode_bounds():
    mean_len = 4.0
    cfg = TraceConfig(jitter_std=0.0, slowdown_prob=0.05,
                      slowdown_factor=2.0, slowdown_mean_len=mean_len,
                      seed=7)
    trace = CapabilityTrace(cfg)
    n = 4000
    slowed = [trace.capability(SPEC, k) < SPEC.c for k in range(n)]
    lengths = _episode_lengths(slowed)
    assert lengths, "episodes must occur at slowdown_prob=0.05 over 4000"
    # geometric episode lengths: empirical mean within 35% of the target
    assert abs(np.mean(lengths) - mean_len) < 0.35 * mean_len
    # stationary occupancy p/(p + 1/L) stays in a sane band
    frac = np.mean(slowed)
    assert 0.05 < frac < 0.40


def test_no_slowdowns_when_probability_zero():
    cfg = TraceConfig(jitter_std=0.0, slowdown_prob=0.0, seed=0)
    trace = CapabilityTrace(cfg)
    assert all(trace.capability(SPEC, k) == SPEC.c for k in range(128))
    assert all(trace.jitter(SPEC, k) == 1.0 for k in range(128))


def test_slowdown_factor_is_exact_divisor():
    cfg = TraceConfig(jitter_std=0.0, slowdown_prob=0.5,
                      slowdown_factor=4.0, seed=1)
    trace = CapabilityTrace(cfg)
    caps = {trace.capability(SPEC, k) for k in range(256)}
    assert caps == {SPEC.c, SPEC.c / 4.0}


def test_jitter_is_mean_one():
    # E[lognormal(-σ²/2, σ)] = 1: jitter must not systematically inflate
    # realized durations relative to the sync timing model
    cfg = TraceConfig(jitter_std=0.3, slowdown_prob=0.0, seed=2)
    trace = CapabilityTrace(cfg)
    samples = np.array([trace.jitter(ClientSpec(cid=c, m=10, c=1.0), k)
                        for c in range(40) for k in range(100)])
    assert (samples > 0).all()
    # 4000 samples: se(mean) ≈ σ/√n ≈ 0.005, so 0.02 is a ±4σ band
    assert abs(samples.mean() - 1.0) < 0.02
    assert abs(np.log(samples).std() - cfg.jitter_std) < 0.02
