"""Cross-engine conformance matrix for model-diverse fleet workloads.

The PR 5 contract: every registered ``FleetWorkload`` (flat-feature MLP,
SmallCNN images, char-LM token sequences, xLSTM char-LM) computes the
SAME arithmetic on every fleet engine.  The matrix is

    workload x engine{loop, batched, sharded} x use_kernel{on, off}

with the per-client ``loop`` execution as the reference: for each cell we
assert parity of the aggregated round params, the selected coreset
medoids (bit-identical), the per-client round stats, and the weighted
test-set eval, all within float32 tolerance.  ``use_kernel=True`` runs
the Pallas selection kernels in interpret mode on CPU — the same
numerics CI gates on.

Also here: the determinism goldens for the new workloads (two identical
``run_fleet`` runs produce byte-identical round-stats/trace sequences —
the fleet-path extension of the PR 1 event-log determinism pattern) and
the schema validation behavior of ``FleetWorkload``.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.fed.fleet.batched import (FleetConfig, FleetEngine,
                                     nominal_budgets, run_fleet,
                                     run_fleet_round)
from repro.fed.fleet.sharded import ShardedFleetEngine, client_mesh
from repro.fed.fleet.workloads import get_workload
from repro.fed.server import make_eval_fn
from repro.fed.simulator import straggler_deadline

WORKLOADS = ("mlp", "cnn", "charlm", "xlstm", "translm")
ENGINES = ("batched", "sharded")        # each compared against "loop"
KERNELS = (True, False)                 # on = interpret-mode Pallas on CPU

# tiny-but-real fleets: small enough that the per-batch-dispatch loop
# reference stays fast, big enough that every workload has coreset
# (straggler) clients AND full-set clients in the cohort
N_CLIENTS, MEAN_M, STD_M, SEED = 6, 24.0, 8.0, 0
CFG = dict(epochs=2, batch_size=8, lr=0.05, seed=0)
STRAGGLER_PCT = 40.0

# Aggregated-params tolerance per workload.  The loop reference jits one
# SGD step per batch dispatch while batched/sharded run the epoch as one
# fused lax.scan, and XLA lowers the 1-input-channel 5x5 conv gradient
# differently between the two program shapes: the (5, 5, 1, 8) first-conv
# leaf picks up ~3e-8/step which SGD amplifies to ~3e-4 per client
# (~5e-5 in the weighted round mean).  Every other leaf and workload
# stays within 1e-5; this is lowering drift, not summation order (vmap
# width is bit-identical), so the cnn column gets a wider pin.
PARAMS_ATOL = {"cnn": 2e-4}

_rounds = {}


def _round(bundles, workload, engine, use_kernel):
    """One fleet round through ``engine``; cached per matrix cell so the
    loop reference is computed once per (workload, kernel) column.
    ``bundles`` is the session-cached conftest factory, so every cell of
    a workload's column shares one dataset build."""
    key = (workload, engine, use_kernel)
    if key in _rounds:
        return _rounds[key]
    b = bundles(workload=workload, n_clients=N_CLIENTS, seed=SEED,
                mean_samples=MEAN_M, std_samples=STD_M)
    cfg = FleetConfig(use_kernel=use_kernel, **CFG)
    deadline = straggler_deadline(b.specs, cfg.epochs, STRAGGLER_PCT)
    budgets = nominal_budgets(b.specs, deadline, cfg.epochs)
    params = b.workload.init(jax.random.PRNGKey(0))
    cids = list(range(len(b.specs)))
    eng = (ShardedFleetEngine(b.workload, cfg, mesh=client_mesh())
           if engine == "sharded" else FleetEngine(b.workload, cfg))
    p, stats = run_fleet_round(eng, params, b.train, cids, budgets,
                               round_seed=0, mode=engine)
    acc, loss = make_eval_fn(b.workload, b.test, 256)(p)
    _rounds[key] = (p, stats, (float(acc), float(loss)),
                    eng.dispatch_count)
    return _rounds[key]


@pytest.mark.parametrize("use_kernel", KERNELS,
                         ids=["kernel_on", "kernel_off"])
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("workload", WORKLOADS)
def test_engine_matches_loop_reference(fleet_bundles, workload, engine,
                                       use_kernel):
    ref_p, ref_s, ref_eval, _ = _round(fleet_bundles, workload, "loop",
                                       use_kernel)
    p, s, ev, _ = _round(fleet_bundles, workload, engine, use_kernel)

    # the straggler (coreset) path AND the full-set path are both live
    assert 0 < ref_s.used_coreset.sum() < ref_s.cids.size

    # aggregated round params within float32 tolerance
    atol = PARAMS_ATOL.get(workload, 1e-5)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)

    # bit-identical medoid selections per client
    assert set(s.medoids) == set(ref_s.medoids)
    for cid in s.medoids:
        np.testing.assert_array_equal(s.medoids[cid], ref_s.medoids[cid])

    # per-client round stats agree (same cohort order contract)
    np.testing.assert_array_equal(s.cids, ref_s.cids)
    np.testing.assert_array_equal(s.m, ref_s.m)
    np.testing.assert_array_equal(s.budgets, ref_s.budgets)
    np.testing.assert_array_equal(s.used_coreset, ref_s.used_coreset)
    np.testing.assert_array_equal(s.work, ref_s.work)
    np.testing.assert_allclose(s.losses, ref_s.losses, atol=1e-5)

    # weighted test-set eval of the aggregated params
    np.testing.assert_allclose(ev, ref_eval, atol=1e-5)


@pytest.mark.parametrize("use_kernel", KERNELS,
                         ids=["kernel_on", "kernel_off"])
@pytest.mark.parametrize("workload", WORKLOADS)
def test_dispatch_accounting_consistent(fleet_bundles, workload,
                                        use_kernel):
    """batched and sharded count *top-level jitted invocations* from the
    one ``count_dispatch`` accounting point, so an identical cohort must
    report identical dispatch counts on both engines; the per-batch loop
    reference dispatches once per jitted step and is strictly costlier."""
    _, _, _, d_batched = _round(fleet_bundles, workload, "batched",
                                use_kernel)
    _, _, _, d_sharded = _round(fleet_bundles, workload, "sharded",
                                use_kernel)
    _, _, _, d_loop = _round(fleet_bundles, workload, "loop", use_kernel)
    assert d_batched == d_sharded
    assert d_loop > d_batched > 0


@pytest.mark.parametrize("workload", WORKLOADS)
def test_kernel_choice_does_not_change_medoids(fleet_bundles, workload):
    """use_kernel on/off is an execution detail of the selection fast
    path: medoid choices must be identical either way."""
    _, s_on, _, _ = _round(fleet_bundles, workload, "batched", True)
    _, s_off, _, _ = _round(fleet_bundles, workload, "batched", False)
    assert set(s_on.medoids) == set(s_off.medoids)
    for cid in s_on.medoids:
        np.testing.assert_array_equal(s_on.medoids[cid], s_off.medoids[cid])


def test_translm_attention_kernel_parity():
    """translm's own tri-state ``use_kernel`` (Pallas flash attention in
    interpret mode vs the identical-math jnp path) is an execution
    detail of the model, separate from the selection-path switch the
    matrix covers: the two implementations' logits must agree within
    float32 tolerance on the same params and tokens."""
    import jax.numpy as jnp

    from repro.data.charlm import VOCAB
    from repro.fed.fleet.workloads import CharTransformer

    wl = get_workload("translm")
    clients = wl.make_clients(n_clients=1, seed=0)
    params = wl.init(jax.random.PRNGKey(0))
    x = jnp.asarray(clients[0]["x"][:8])
    on = CharTransformer(vocab=VOCAB, use_kernel=True).logits(params, x)
    off = CharTransformer(vocab=VOCAB, use_kernel=False).logits(params, x)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off), atol=1e-4)


# ---------------------------------------------------------------------------
# determinism goldens for the new workloads
# ---------------------------------------------------------------------------

def _stats_bytes(stats):
    """FleetRoundStats as a canonical byte string (golden comparison)."""
    parts = [stats.cids.tobytes(), stats.m.tobytes(),
             stats.budgets.tobytes(), stats.used_coreset.tobytes(),
             stats.work.tobytes(), stats.losses.tobytes()]
    for cid in sorted(stats.medoids):
        parts.append(np.asarray(stats.medoids[cid]).tobytes())
    return b"".join(parts)


@pytest.mark.parametrize("workload", ("cnn", "charlm"))
def test_run_fleet_determinism_golden(fleet_bundles, workload):
    """Two identical runs per new workload: byte-identical round stats,
    byte-identical params, and identical trace-perturbed histories —
    the PR 1 event-log determinism pattern extended to the fleet path."""
    b = fleet_bundles(workload=workload, n_clients=N_CLIENTS, seed=SEED,
                      mean_samples=MEAN_M, std_samples=STD_M,
                      scenario="flash_crowd")
    cfg = FleetConfig(**CFG)
    deadline = straggler_deadline(b.specs, cfg.epochs, STRAGGLER_PCT)
    budgets = nominal_budgets(b.specs, deadline, cfg.epochs)
    params = b.workload.init(jax.random.PRNGKey(0))
    cids = list(range(len(b.specs)))

    def one_round():
        engine = FleetEngine(b.workload, cfg)
        return run_fleet_round(engine, params, b.train, cids, budgets,
                               round_seed=0, mode="batched")

    (p1, s1), (p2, s2) = one_round(), one_round()
    assert _stats_bytes(s1) == _stats_bytes(s2)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.asarray(a).tobytes() == np.asarray(c).tobytes()

    def full_run():
        return run_fleet(b.workload, b.train, b.specs, cfg, rounds=2,
                         trace=b.trace, test_data=b.test)

    ra, rb = full_run(), full_run()
    assert [dataclasses.astuple(r) for r in ra["history"]] == \
        [dataclasses.astuple(r) for r in rb["history"]]
    for a, c in zip(jax.tree.leaves(ra["params"]),
                    jax.tree.leaves(rb["params"])):
        assert np.asarray(a).tobytes() == np.asarray(c).tobytes()
    # the capability trace actually perturbed the recorded durations
    plain = run_fleet(b.workload, b.train, b.specs, cfg, rounds=2,
                      test_data=b.test)
    assert ra["history"][0].client_times != plain["history"][0].client_times


# ---------------------------------------------------------------------------
# async_fleet column: the event-driven engine per workload
# ---------------------------------------------------------------------------

_async_runs = {}


def _async_run(bundles, workload, engine):
    """One short async_fleet run; cached per (workload, engine) cell."""
    key = (workload, engine)
    if key in _async_runs:
        return _async_runs[key]
    from repro.fed.fleet.async_engine import (AsyncFleetConfig,
                                              run_async_fleet)
    b = bundles(workload=workload, n_clients=N_CLIENTS, seed=SEED,
                mean_samples=MEAN_M, std_samples=STD_M)
    cfg = AsyncFleetConfig(max_updates=2, buffer_k=3, concurrency=4,
                           straggler_pct=STRAGGLER_PCT, **CFG)
    _async_runs[key] = run_async_fleet(b.workload, b.train, b.specs, cfg,
                                       test_data=b.test, engine=engine)
    return _async_runs[key]


@pytest.mark.parametrize("workload", WORKLOADS)
def test_async_fleet_matches_loop_reference(fleet_bundles, workload):
    """The async_fleet column of the matrix: the event-driven engine's
    micro-batched group programs compute the same arithmetic as per-client
    loop execution — byte-identical event schedules (the virtual clock is
    a pure function of seeds, never of execution speed) and params within
    the workload's pin."""
    ref = _async_run(fleet_bundles, workload, "loop")
    out = _async_run(fleet_bundles, workload, "batched")
    assert ref["event_log"] == out["event_log"]
    assert len(out["event_log"]) > 0
    atol = PARAMS_ATOL.get(workload, 1e-5)
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)
    # micro-batching means jitted group programs, not per-client dispatch
    tel = out["telemetry"]
    assert 0 < tel["n_group_dispatches"] <= tel["n_dispatches"]


# ---------------------------------------------------------------------------
# workload schema + registry behavior
# ---------------------------------------------------------------------------

def test_registry_names_and_schemas():
    for name in ("mlp", "cnn", "charlm", "xlstm", "translm"):
        wl = get_workload(name)
        assert wl.name == name
        assert set(wl.schema) == {"x", "y"}
        clients = wl.make_clients(n_clients=2, seed=1)
        wl.validate_clients(clients)      # no raise
    with pytest.raises(ValueError, match="unknown fleet workload"):
        get_workload("resnet152")


def test_schema_validation_rejects_mismatches():
    wl = get_workload("cnn")
    good = wl.make_clients(n_clients=1, seed=0)
    with pytest.raises(ValueError, match="fields"):
        wl.validate_clients([{"x": np.asarray(good[0]["x"])}])
    with pytest.raises(ValueError, match="shape"):
        bad = dict(good[0], x=good[0]["x"][..., :7])
        wl.validate_clients([bad])
    with pytest.raises(ValueError, match="dtype"):
        bad = dict(good[0], y=good[0]["y"].astype(np.int64))
        wl.validate_clients([bad])
    # a top-level "weights" field is engine-reserved and schema-exempt
    wl.validate_clients([dict(
        good[0], weights=np.ones(len(good[0]["y"]), np.float32))])


@pytest.mark.parametrize("workload", ("cnn", "charlm"))
def test_scenario_fleet_runtime_per_workload(workload):
    """run_scenario's workload axis: registry-built clients through the
    fleet runtime, deterministic, with the workload stamped on the
    result."""
    from repro.fed.fleet.scenarios import run_scenario

    def go():
        return run_scenario("device_classes", "fleet", workload=workload,
                            n_clients=4, seed=0, rounds=2, epochs=2,
                            batch_size=8)
    out, again = go(), go()
    assert out["workload"] == workload and out["runtime"] == "fleet"
    assert len(out["history"]) == 2
    assert [dataclasses.astuple(r) for r in out["history"]] == \
        [dataclasses.astuple(r) for r in again["history"]]
