"""End-to-end system behaviour: the paper's headline claims on a reduced
Synthetic benchmark (the qualitative shape of Table 2 / Fig. 3-5).

These are the integration tests for the full stack: data generator ->
straggler simulator -> strategies (incl. FedCore's feature extraction,
k-medoids coreset, weighted coreset epochs) -> aggregation -> eval.
"""
import numpy as np
import pytest

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.server import FLConfig, run_federated, summarize
from repro.fed.simulator import make_client_specs
from repro.fed.strategies import FedAvg, FedAvgDS, FedCore, FedProx, LocalTrainer
from repro.models.small import LogisticRegression


@pytest.fixture(scope="module")
def fl_world():
    clients = synthetic_dataset(1.0, 1.0, n_clients=10, mean_samples=120,
                                std_samples=100, seed=0)
    train, test = train_test_split_clients(clients)
    rng = np.random.default_rng(0)
    specs = make_client_specs([len(d["y"]) for d in train], rng)
    model = LogisticRegression()
    cfg = FLConfig(rounds=8, clients_per_round=5, epochs=5, batch_size=8,
                   lr=0.05, straggler_pct=30.0, seed=0, eval_every=4)
    return model, train, test, specs, cfg


@pytest.fixture(scope="module")
def results(fl_world):
    model, train, test, specs, cfg = fl_world
    out = {}
    for name, factory in {
        "fedavg": lambda: FedAvg(LocalTrainer(model, cfg.lr, cfg.batch_size)),
        "fedavg_ds": lambda: FedAvgDS(LocalTrainer(model, cfg.lr,
                                                   cfg.batch_size)),
        "fedprox": lambda: FedProx(LocalTrainer(model, cfg.lr,
                                                cfg.batch_size,
                                                prox_mu=0.1)),
        "fedcore": lambda: FedCore(LocalTrainer(model, cfg.lr,
                                                cfg.batch_size)),
    }.items():
        out[name] = run_federated(model, train, specs, factory(), cfg, test)
    return out


def test_deadline_aware_methods_meet_deadline(results):
    for name in ("fedavg_ds", "fedprox", "fedcore"):
        out = results[name]
        s = summarize(out["history"], out["deadline"])
        assert s["max_round_time_normalized"] <= 1.001, name


def test_fedavg_exceeds_deadline(results):
    out = results["fedavg"]
    s = summarize(out["history"], out["deadline"])
    assert s["max_round_time_normalized"] > 1.0


def test_fedcore_beats_drop_stragglers_accuracy(results):
    acc_core = summarize(results["fedcore"]["history"],
                         results["fedcore"]["deadline"])["final_test_acc"]
    acc_ds = summarize(results["fedavg_ds"]["history"],
                       results["fedavg_ds"]["deadline"])["final_test_acc"]
    assert acc_core > acc_ds


def test_fedcore_accuracy_close_to_fedavg(results):
    """Table 2: coreset training does not degrade accuracy materially."""
    acc_core = summarize(results["fedcore"]["history"],
                         results["fedcore"]["deadline"])["final_test_acc"]
    acc_avg = summarize(results["fedavg"]["history"],
                        results["fedavg"]["deadline"])["final_test_acc"]
    assert acc_core >= acc_avg - 0.05


def test_fedcore_round_time_speedup_vs_fedavg(results):
    """The headline: FedCore rounds are bounded by τ while FedAvg's are
    stretched by stragglers."""
    t_core = summarize(results["fedcore"]["history"],
                       results["fedcore"]["deadline"])["mean_round_time"]
    t_avg = summarize(results["fedavg"]["history"],
                      results["fedavg"]["deadline"])["mean_round_time"]
    assert t_avg > t_core
