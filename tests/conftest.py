import os

# Tests run on the single host CPU device (the dry-run, and ONLY the dry-run,
# forces 512 host devices via XLA_FLAGS in launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses  # noqa: E402
from typing import Any, List, Optional  # noqa: E402

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# shared fleet test workloads
#
# One builder replaces the synthetic-client constructions that used to be
# copy-pasted across test_fleet.py, test_fleet_sharded.py, and
# test_kmedoids_fused.py, and parameterizes them by FleetWorkload so the
# conformance matrix runs the same construction for mlp / cnn / charlm /
# xlstm.  Plain functions (not only fixtures) on purpose: the sharded
# parity test re-execs itself as a multi-device subprocess and imports
# ``conftest`` directly.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetBundle:
    """A ready-to-run fleet test workload: model + split data + specs."""
    workload: Any                 # FleetWorkload (usable as the model)
    train: List[Any]
    test: Any
    specs: List[Any]
    trace: Optional[Any] = None   # TraceConfig when built from a scenario

    @property
    def model(self):
        return self.workload


def fleet_bundle(workload: str = "mlp", n_clients: int = 16, seed: int = 3,
                 mean_samples: float = 60.0, std_samples: float = 40.0,
                 test_frac: float = 0.1,
                 scenario: Optional[str] = None) -> FleetBundle:
    """Build a federated test fleet for any registered workload.

    ``scenario=None`` draws client capabilities with ``make_client_specs``
    (seeded by ``seed``); a scenario name draws them from the registry via
    ``build_scenario`` and also returns the scenario's TraceConfig.
    """
    from repro.data.partition import train_test_split_clients
    from repro.fed.fleet.scenarios import build_scenario
    from repro.fed.fleet.workloads import client_sizes, get_workload
    from repro.fed.simulator import make_client_specs

    wl = get_workload(workload)
    clients = wl.make_clients(n_clients=n_clients, seed=seed,
                              mean_samples=mean_samples,
                              std_samples=std_samples)
    wl.validate_clients(clients)
    train, test = train_test_split_clients(clients, test_frac=test_frac)
    sizes = client_sizes(train)
    trace = None
    if scenario is not None:
        specs, trace = build_scenario(scenario, sizes, seed)
    else:
        specs = make_client_specs(sizes, np.random.default_rng(seed))
    return FleetBundle(workload=wl, train=train, test=test, specs=specs,
                       trace=trace)


def fixed_size_clients(workload: str = "mlp", n_clients: int = 6,
                       m: int = 40, seed: int = 0):
    """Same-size clients (exactly ``m`` samples each), so one budget maps
    to one cohort group — what the kernel/dispatch-count tests rely on.
    Returns ``(FleetWorkload, clients_data)``."""
    import jax

    from repro.fed.fleet.workloads import client_num_samples, get_workload

    wl = get_workload(workload)
    # oversample (tiny spread keeps every draw >= 2m), then slice to m
    clients = wl.make_clients(n_clients=n_clients, seed=seed,
                              mean_samples=float(2 * m), std_samples=0.1)
    clients = [jax.tree.map(lambda v: v[:m], d) for d in clients]
    assert all(client_num_samples(d) == m for d in clients)
    return wl, clients


@pytest.fixture(scope="session")
def fleet_bundles():
    """Session-cached ``fleet_bundle`` factory: identical kwargs return
    the same bundle object, so parametrized matrices don't rebuild (or
    re-split) a workload's dataset per test."""
    cache = {}

    def get(**kwargs) -> FleetBundle:
        key = tuple(sorted(kwargs.items()))
        if key not in cache:
            cache[key] = fleet_bundle(**kwargs)
        return cache[key]

    return get
