import os

# Tests run on the single host CPU device (the dry-run, and ONLY the dry-run,
# forces 512 host devices via XLA_FLAGS in launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
