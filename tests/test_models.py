"""Model substrate tests: attention impls agree, SSD scan vs sequential,
MoE dispatch vs dense oracle, decode continuation == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import mamba2, moe, xlstm
from repro.models.attention import init_attention, multihead_attention
from repro.models.model import Model


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 9])
@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_chunked_matches_naive(window, kv_heads):
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=kv_heads, d_ff=128,
                      vocab_size=100)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 64))
    a = multihead_attention(p, cfg, x, causal=True, window=window,
                            impl="naive")
    b = multihead_attention(p, cfg, x, causal=True, window=window,
                            impl="chunked")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_cross_attention_matches():
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 19, 64))
    kvx = jax.random.normal(jax.random.PRNGKey(2), (2, 13, 64))
    a = multihead_attention(p, cfg, x, causal=False, impl="naive", kv_x=kvx,
                            use_rope=False)
    b = multihead_attention(p, cfg, x, causal=False, impl="chunked",
                            kv_x=kvx, use_rope=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# mamba2 / SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_vs_sequential(chunk):
    b, s, nh, hd, n = 2, 23, 4, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y1, h1 = mamba2.ssd_chunked(x, a, B, C, chunk=chunk)
    y2, h2 = mamba2.ssd_sequential(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)


def test_mamba_block_decode_continuation():
    cfg = ModelConfig(d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      ssm_state=8, ssm_headdim=16, ssm_chunk=8)
    p = mamba2.init_mamba2(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(4), (2, 12, 32))
    y_full, _ = mamba2.mamba2_block(p, cfg, u)
    y_pre, st = mamba2.mamba2_block(p, cfg, u[:, :8])
    ys = [y_pre]
    for t in range(8, 12):
        y_t, st = mamba2.mamba2_block(p, cfg, u[:, t:t + 1], st, decode=True)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_oracle():
    cfg = ModelConfig(d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      n_experts=4, moe_capacity_factor=4.0)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    a, aux_a = moe.moe_ffn(p, cfg, x)
    b, aux_b = moe.moe_ffn_dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(float(aux_a), float(aux_b), rtol=1e-5)


def test_moe_capacity_drops_dont_nan():
    cfg = ModelConfig(d_model=16, n_heads=4, n_kv_heads=4, d_ff=32,
                      n_experts=4, moe_capacity_factor=0.5)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y, aux = moe.moe_ffn(p, cfg, x)
    assert not bool(jnp.isnan(y).any())
    assert float(aux) > 0


def test_moe_grads_flow():
    cfg = ModelConfig(d_model=16, n_heads=4, n_kv_heads=4, d_ff=32,
                      n_experts=4)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))

    def loss(p_):
        y, aux = moe.moe_ffn(p_, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # router must receive gradient through the gate + aux loss
    assert float(jnp.linalg.norm(g["router"])) > 0


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["m", "s"])
def test_xlstm_decode_continuation(kind):
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, d_ff=0)
    init = xlstm.init_mlstm if kind == "m" else xlstm.init_slstm
    block = xlstm.mlstm_block if kind == "m" else xlstm.slstm_block
    p = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64))
    y_full, _ = block(p, cfg, x)
    y_pre, st = block(p, cfg, x[:, :6])
    ys = [y_pre]
    for t in range(6, 10):
        y_t, st = block(p, cfg, x[:, t:t + 1], st, decode=True)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dense decode == forward (KV-cache correctness end-to-end)
# ---------------------------------------------------------------------------

def test_dense_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=50)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 50)
    logits_full, _, _ = m.forward(p, {"tokens": tokens}, impl="naive")
    st = m.init_decode_state(p, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(9):
        lg, st = m.decode_step(p, st, tokens[:, t:t + 1],
                               jnp.asarray(t, jnp.int32))
        outs.append(lg)
    logits_inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_inc), rtol=2e-4, atol=2e-4)


def test_sliding_window_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=50,
                      attention_window=4)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 50)
    logits_full, _, _ = m.forward(p, {"tokens": tokens}, impl="naive")
    st = m.init_decode_state(p, 1, 12, dtype=jnp.float32)
    outs = []
    for t in range(12):
        lg, st = m.decode_step(p, st, tokens[:, t:t + 1],
                               jnp.asarray(t, jnp.int32))
        outs.append(lg)
    logits_inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_inc), rtol=2e-4, atol=2e-4)
