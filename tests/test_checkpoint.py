"""Checkpoint round-trip + resume tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, load_pytree,
                              load_server_state, save_pytree,
                              save_server_state)


def _tree():
    return {"a": {"b": jnp.ones((3, 2)), "c": jnp.arange(4)},
            "d": [jnp.zeros(2), jnp.full((2, 2), 7.0)]}


def test_roundtrip_with_structure(tmp_path):
    tree = _tree()
    path = str(tmp_path / "x.npz")
    save_pytree(path, tree)
    back = load_pytree(path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_nested_dict_reconstruction(tmp_path):
    tree = {"x": {"y": jnp.ones(3)}, "z": jnp.zeros(2)}
    path = str(tmp_path / "y.npz")
    save_pytree(path, tree)
    back = load_pytree(path)
    np.testing.assert_array_equal(np.asarray(back["x"]["y"]), np.ones(3))


def test_server_state_resume(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for r in (0, 3, 7):
        save_server_state(d, r, tree, extra={"note": "test"})
    assert latest_checkpoint(d).endswith("ckpt_000007.npz")
    params, rnd = load_server_state(d, like=tree)
    assert rnd == 7
    assert params is not None


def test_load_missing_returns_none(tmp_path):
    params, rnd = load_server_state(str(tmp_path / "nope"))
    assert params is None and rnd == -1
