"""Checkpoint round-trip + resume tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, load_pytree,
                              load_server_meta, load_server_state,
                              save_pytree, save_server_state)


def _tree():
    return {"a": {"b": jnp.ones((3, 2)), "c": jnp.arange(4)},
            "d": [jnp.zeros(2), jnp.full((2, 2), 7.0)]}


def test_roundtrip_with_structure(tmp_path):
    tree = _tree()
    path = str(tmp_path / "x.npz")
    save_pytree(path, tree)
    back = load_pytree(path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_nested_dict_reconstruction(tmp_path):
    tree = {"x": {"y": jnp.ones(3)}, "z": jnp.zeros(2)}
    path = str(tmp_path / "y.npz")
    save_pytree(path, tree)
    back = load_pytree(path)
    np.testing.assert_array_equal(np.asarray(back["x"]["y"]), np.ones(3))


def test_server_state_resume(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for r in (0, 3, 7):
        save_server_state(d, r, tree, extra={"note": "test"})
    assert latest_checkpoint(d).endswith("ckpt_000007.npz")
    params, rnd = load_server_state(d, like=tree)
    assert rnd == 7
    assert params is not None


def test_load_missing_returns_none(tmp_path):
    params, rnd = load_server_state(str(tmp_path / "nope"))
    assert params is None and rnd == -1


def test_roundtrip_without_like_preserves_dtypes_and_treedef(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "opt": (jnp.full(2, 0.5, jnp.float32), np.arange(3, dtype=np.int64)),
            "log": [np.float64(1.5), np.ones(2, np.float32)],
            "flag": None}
    path = str(tmp_path / "d.npz")
    save_pytree(path, tree)
    back = load_pytree(path)          # no `like`: structure from the file
    assert jax.tree.structure(back, is_leaf=lambda x: x is None) == \
        jax.tree.structure(tree, is_leaf=lambda x: x is None)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_checkpoint_skips_unreadable_files(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_server_state(d, 2, tree)
    # a partially-written (garbage) npz with a higher round number must
    # not shadow the last good checkpoint
    with open(os.path.join(d, "ckpt_000009.npz"), "wb") as f:
        f.write(b"\x00not-a-zipfile")
    assert latest_checkpoint(d).endswith("ckpt_000002.npz")
    params, rnd = load_server_state(d, like=tree)
    assert rnd == 2 and params is not None


def test_load_server_meta_roundtrip(tmp_path):
    d = str(tmp_path)
    save_server_state(d, 5, _tree(),
                      extra={"kind": "fleet", "rng": [1, 2, 3]})
    meta = load_server_meta(d)
    assert meta["kind"] == "fleet"
    assert meta["rng"] == [1, 2, 3]
    assert load_server_meta(str(tmp_path / "nope")) is None
