"""Fleet subsystem tests: batched kernels/solvers, cohort grouping,
batched-vs-loop engine parity, adaptive participation, and scenario
determinism through both the sync server and the async event runtime."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coreset import coreset_budget
from repro.core.kmedoids import (kmedoids_batched, kmedoids_jax,
                                 kmedoids_masked, pairwise_sq_dists)
from repro.fed.fleet.batched import (FleetConfig, FleetEngine, _floor_pow4,
                                     _next_pow2, make_cohort_groups,
                                     nominal_budgets, run_fleet,
                                     run_fleet_round)
from repro.fed.fleet.scenarios import SCENARIOS, build_scenario, run_scenario
from repro.fed.fleet.scheduler import (AdaptiveParticipation,
                                       ParticipationConfig)
from repro.fed.fleet.sharded import ShardedFleetEngine, client_mesh
from repro.fed.simulator import (ClientSpec, TraceConfig,
                                 straggler_deadline)
from repro.kernels.ops import pairwise_l2, pairwise_l2_batched


@pytest.fixture(scope="module")
def fleet_fl(fleet_bundles):
    # the deduped mlp bundle from conftest: same data/specs the sharded
    # and conformance suites build from
    b = fleet_bundles(workload="mlp", n_clients=16, seed=3)
    return b.model, b.train, b.test, b.specs


# ---------------------------------------------------------------------------
# batched primitives
# ---------------------------------------------------------------------------

def test_pairwise_l2_batched_matches_unbatched():
    x = np.random.default_rng(0).normal(size=(3, 40, 60)).astype(np.float32)
    xj = jnp.asarray(x)
    for squared in (True, False):
        ref = np.stack([np.asarray(pairwise_l2(xj[c], squared=squared))
                        for c in range(3)])
        got = np.asarray(pairwise_l2_batched(xj, squared=squared,
                                             use_kernel=True))
        np.testing.assert_allclose(got, ref, atol=2e-4)


def test_kmedoids_masked_matches_unpadded():
    rng = np.random.default_rng(1)
    m, m_pad, k = 21, 32, 5
    x = rng.normal(size=(m, 6)).astype(np.float32)
    D = np.sqrt(np.maximum(np.asarray(pairwise_sq_dists(jnp.asarray(x))), 0))
    Dp = rng.normal(size=(m_pad, m_pad)).astype(np.float32) * 50  # garbage
    Dp[:m, :m] = D
    valid = np.arange(m_pad) < m
    ref = kmedoids_jax(jnp.asarray(D), k)
    got = kmedoids_masked(jnp.asarray(Dp), jnp.asarray(valid), k)
    np.testing.assert_array_equal(np.asarray(got.medoids),
                                  np.asarray(ref.medoids))
    np.testing.assert_array_equal(np.asarray(got.weights),
                                  np.asarray(ref.weights))
    np.testing.assert_allclose(float(got.objective), float(ref.objective),
                               rtol=1e-5)
    assert (np.asarray(got.assignment)[m:] == -1).all()


def test_kmedoids_batched_equals_per_lane():
    rng = np.random.default_rng(2)
    C, m_pad, k = 5, 24, 3
    Ds, vs = [], []
    for _ in range(C):
        m = int(rng.integers(6, m_pad + 1))
        x = rng.normal(size=(m, 4)).astype(np.float32)
        D = np.sqrt(np.maximum(
            np.asarray(pairwise_sq_dists(jnp.asarray(x))), 0))
        Dp = np.zeros((m_pad, m_pad), np.float32)
        Dp[:m, :m] = D
        Ds.append(Dp)
        vs.append(np.arange(m_pad) < m)
    Ds, vs = jnp.asarray(np.stack(Ds)), jnp.asarray(np.stack(vs))
    batched = kmedoids_batched(Ds, vs, k)
    for c in range(C):
        lane = kmedoids_masked(Ds[c], vs[c], k)
        np.testing.assert_array_equal(np.asarray(batched.medoids[c]),
                                      np.asarray(lane.medoids))


# ---------------------------------------------------------------------------
# cohort grouping
# ---------------------------------------------------------------------------

def test_pow_helpers():
    assert [_next_pow2(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert [_floor_pow4(n) for n in (1, 3, 4, 15, 16, 80)] == \
        [1, 1, 4, 4, 16, 64]


def test_floor_pow4_ladder_and_group_keys():
    """Budget quantization is a power-of-FOUR ladder (what the
    make_cohort_groups docstring promises), and group keys quantize member
    budgets with it — never exceeding any member's true budget."""
    # every rung of the ladder up to 4^5
    for e in range(6):
        lo, hi = 4 ** e, 4 ** (e + 1)
        for n in (lo, lo + 1, hi - 1):
            assert _floor_pow4(n) == lo, n
        assert _floor_pow4(hi) == hi
    # pow2-but-not-pow4 values round DOWN to the pow4 below
    assert [_floor_pow4(n) for n in (2, 8, 32, 128)] == [1, 4, 16, 64]

    # group keys: m=24 pads to 32 (next pow2 of 3 batches x B=8); budgets
    # 9 and 20 quantize to the (32, 4)/(32, 16) buckets; b >= m means k=0
    data = [{"x": np.zeros((24, 2), np.float32),
             "y": np.zeros(24, np.int32)} for _ in range(3)]
    cfg = FleetConfig(epochs=1, batch_size=8, seed=0)
    budgets = {0: 9, 1: 20, 2: 24}
    groups = make_cohort_groups(data, [0, 1, 2], budgets, cfg, 0)
    keys = {(g.valid.shape[1], g.k): g.cids.tolist() for g in groups}
    assert keys == {(32, 4): [0], (32, 16): [1], (32, 0): [2]}
    for g in groups:
        for cid in g.cids:
            assert g.k <= budgets[cid] or g.k == 0


def test_cohort_groups_partition_and_pad(fleet_fl):
    _, train, _, specs = fleet_fl
    cfg = FleetConfig(epochs=2, batch_size=16, seed=0)
    deadline = straggler_deadline(specs, cfg.epochs, 30.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    cids = list(range(len(specs)))
    groups = make_cohort_groups(train, cids, budgets, cfg, round_seed=1)
    seen = np.concatenate([g.cids for g in groups])
    assert sorted(seen.tolist()) == cids          # exact partition
    for g in groups:
        c, m_pad = g.valid.shape
        assert m_pad % cfg.batch_size == 0
        assert g.perms.shape == (c, cfg.epochs, m_pad)
        for i in range(c):
            # valid prefix mask matches true sizes; perms are permutations
            assert g.valid[i].sum() == g.m[i] <= m_pad
            for e in range(cfg.epochs):
                assert sorted(g.perms[i, e].tolist()) == list(range(m_pad))
            if g.k > 0:   # quantized budget never exceeds the true budget
                assert g.k <= budgets[g.cids[i]]


def test_cohort_groups_rng_independent_of_grouping(fleet_fl):
    _, train, _, specs = fleet_fl
    cfg = FleetConfig(epochs=2, batch_size=16, seed=0)
    deadline = straggler_deadline(specs, cfg.epochs, 30.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    full = make_cohort_groups(train, list(range(len(specs))), budgets, cfg, 0)
    solo = make_cohort_groups(train, [5], budgets, cfg, 0)
    g, idx = next((g, list(g.cids).index(5)) for g in full if 5 in g.cids)
    np.testing.assert_array_equal(g.perms[idx], solo[0].perms[0])


# ---------------------------------------------------------------------------
# engine parity + determinism
# ---------------------------------------------------------------------------

def test_batched_engine_matches_per_client_loop(fleet_fl):
    model, train, _, specs = fleet_fl
    cfg = FleetConfig(epochs=3, batch_size=16, lr=0.05, seed=0)
    deadline = straggler_deadline(specs, cfg.epochs, 40.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    engine = FleetEngine(model, cfg)
    params = model.init(jax.random.PRNGKey(0))
    cids = list(range(len(specs)))
    pb, sb = run_fleet_round(engine, params, train, cids, budgets,
                             round_seed=0, batched=True)
    pl, sl = run_fleet_round(engine, params, train, cids, budgets,
                             round_seed=0, batched=False)
    assert sb.used_coreset.sum() > 0      # the straggler path is exercised
    for a, b in zip(jax.tree.leaves(pb), jax.tree.leaves(pl)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert set(sb.medoids) == set(sl.medoids)
    for cid in sb.medoids:
        np.testing.assert_array_equal(sb.medoids[cid], sl.medoids[cid])
    np.testing.assert_allclose(sb.losses, sl.losses, atol=1e-5)


class _ScriptedScheduler:
    """Minimal select/budget/observe/record_round scheduler driving a
    fixed per-round cohort script."""

    def __init__(self, cohorts, specs):
        self.cohorts = list(cohorts)
        self.specs = specs
        self.observed = []
        self._r = 0

    def select(self):
        cohort = self.cohorts[min(self._r, len(self.cohorts) - 1)]
        self._r += 1
        return np.asarray(cohort, np.int64)

    def budget(self, cid, deadline, epochs):
        return self.specs[cid].m    # full-set training for everyone

    def observe(self, cid, work, duration):
        self.observed.append((cid, work, duration))

    def record_round(self, train_loss):
        pass


def test_empty_cohort_round_is_noop(fleet_fl):
    """A scheduler may select an empty cohort (e.g. every candidate
    infeasible): the round must keep the previous params and record zero
    participants instead of crashing on an empty aggregation."""
    model, train, _, specs = fleet_fl
    cfg = FleetConfig(epochs=2, batch_size=16, seed=0)
    engine = FleetEngine(model, cfg)
    params = model.init(jax.random.PRNGKey(0))

    # direct round-level check: params pass through bit-identically
    p2, stats = run_fleet_round(engine, params, train, [], {}, round_seed=0)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats.cids.size == 0 and stats.losses.size == 0
    assert stats.medoids == {}

    # driver-level: an empty middle round yields a zero-participant record
    sched = _ScriptedScheduler([[0, 1], [], [0, 1]], specs)
    out = run_fleet(model, train, specs, cfg, rounds=3, scheduler=sched)
    rec = out["history"][1]
    assert rec.n_participants == 0
    assert rec.sim_round_time == 0.0 and rec.client_times == []
    assert np.isnan(rec.train_loss)
    # surrounding rounds still train
    assert out["history"][0].n_participants == 2
    assert out["history"][2].n_participants == 2


def test_fleet_trace_indexed_per_client_dispatch(fleet_fl):
    """The CapabilityTrace is defined per (client, dispatch): a client
    absent for some rounds must draw its *next* trace entry on return,
    exactly as the sync server and async event loop index it — not the
    round number (the old bug)."""
    from repro.fed.simulator import CapabilityTrace

    model, train, _, specs = fleet_fl
    cfg = FleetConfig(epochs=2, batch_size=16, seed=0)
    tc = TraceConfig(jitter_std=0.3, slowdown_prob=0.5,
                     slowdown_factor=4.0, seed=7)
    # client 0 participates every round; client 1 skips rounds 1-2
    cohorts = [[0, 1], [0], [0], [0, 1]]
    sched = _ScriptedScheduler(cohorts, specs)
    out = run_fleet(model, train, specs, cfg, rounds=4, scheduler=sched,
                    trace=tc)

    # reference: a fresh trace indexed by per-client dispatch counts —
    # the indexing contract shared with events.py (dispatch_counts) and
    # server.py; same (seed, cid, index) => identical draws everywhere
    ref = CapabilityTrace(tc)
    counts = {cid: 0 for cid in range(len(specs))}
    for r, cohort in enumerate(cohorts):
        rec = out["history"][r]
        assert rec.n_participants == len(cohort)
        expect = []
        for cid in cohort:
            k = counts[cid]
            counts[cid] += 1
            s = specs[cid]
            work = cfg.epochs * s.m      # full-set budgets (see scheduler)
            expect.append(work / ref.capability(s, k) * ref.jitter(s, k))
        # client_times follow cohort-group order; compare as multisets
        np.testing.assert_allclose(sorted(rec.client_times), sorted(expect),
                                   rtol=1e-12)
    # client 1's second appearance (round 3) drew dispatch index 1; the
    # old code indexed by round number and would have drawn entry 3
    s1 = specs[1]
    assert (ref.capability(s1, 1), ref.jitter(s1, 1)) != \
        (ref.capability(s1, 3), ref.jitter(s1, 3))


def test_run_fleet_deterministic_and_trace_sensitive(fleet_fl):
    model, train, test, specs = fleet_fl
    _, trace = build_scenario("flash_crowd", [s.m for s in specs], seed=0)
    cfg = FleetConfig(epochs=2, batch_size=16, seed=0)

    def go():
        return run_fleet(model, train, specs, cfg, rounds=2, trace=trace,
                         test_data=test)
    a, b = go(), go()
    assert [dataclasses.astuple(r) for r in a["history"]] == \
        [dataclasses.astuple(r) for r in b["history"]]
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # the trace perturbs durations relative to a no-trace run
    c = run_fleet(model, train, specs, cfg, rounds=2, test_data=test)
    assert a["history"][0].client_times != c["history"][0].client_times


# ---------------------------------------------------------------------------
# sharded engine (single-device mesh; the 4-virtual-device parity run
# lives in test_fleet_sharded.py)
# ---------------------------------------------------------------------------

def test_sharded_engine_matches_batched(fleet_fl):
    """shard_map execution + psum-tree aggregation reproduce the batched
    engine: identical medoids, params within float32 tolerance.  On one
    device this exercises the full sharded code path (placement,
    padding, psum) without cross-device splits."""
    model, train, _, specs = fleet_fl
    cfg = FleetConfig(epochs=3, batch_size=16, lr=0.05, seed=0)
    deadline = straggler_deadline(specs, cfg.epochs, 40.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    params = model.init(jax.random.PRNGKey(0))
    cids = list(range(len(specs)))
    pb, sb = run_fleet_round(FleetEngine(model, cfg), params, train, cids,
                             budgets, round_seed=0, mode="batched")
    eng = ShardedFleetEngine(model, cfg, mesh=client_mesh())
    ps, ss = run_fleet_round(eng, params, train, cids, budgets,
                             round_seed=0, mode="sharded")
    assert sb.used_coreset.sum() > 0
    for a, b in zip(jax.tree.leaves(pb), jax.tree.leaves(ps)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert set(sb.medoids) == set(ss.medoids)
    for cid in sb.medoids:
        np.testing.assert_array_equal(sb.medoids[cid], ss.medoids[cid])
    np.testing.assert_allclose(sb.losses, ss.losses, atol=1e-5)


def test_run_fleet_sharded_engine_option(fleet_fl):
    """run_fleet(engine="sharded") matches the batched driver end to end
    (on one device it falls back to the batched path; on a multi-device
    host it runs the mesh engine — either way the history must agree)."""
    model, train, test, specs = fleet_fl
    cfg = FleetConfig(epochs=2, batch_size=16, seed=0)
    a = run_fleet(model, train, specs, cfg, rounds=2, test_data=test,
                  engine="sharded")
    b = run_fleet(model, train, specs, cfg, rounds=2, test_data=test,
                  engine="batched")
    assert a["engine"] == "sharded"
    assert a["engine_mode"] == ("batched" if a["n_devices"] == 1
                                else "sharded")
    for ra, rb in zip(a["history"], b["history"]):
        assert ra.n_participants == rb.n_participants
        np.testing.assert_allclose(ra.train_loss, rb.train_loss, atol=1e-5)
        np.testing.assert_allclose(ra.test_acc, rb.test_acc, atol=1e-5)


# ---------------------------------------------------------------------------
# adaptive participation
# ---------------------------------------------------------------------------

def _specs(caps, m=50):
    return [ClientSpec(cid=i, m=m, c=float(c)) for i, c in enumerate(caps)]


def test_scheduler_selects_fastest_and_explores():
    specs = _specs([1.0, 9.0, 8.0, 0.1, 7.0, 0.2, 0.3, 6.0])
    sched = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=4, explore_frac=0.25, seed=0))
    cohort = sched.select()
    assert len(cohort) == 4
    # 3 fastest guaranteed, 1 explored from the rest
    assert {1, 2, 4} <= set(cohort.tolist())
    # dispatch weights: cohort at 1.0, soft exploration tail at explore_frac
    mask = sched.eligible_mask()
    assert (mask == 1.0).sum() == 4 and (mask[[1, 2, 4, 7]] == 1.0).all()
    assert (mask[[0, 3, 5, 6]] == 0.25).all()


def test_scheduler_doubles_on_plateau():
    specs = _specs(np.ones(64))
    sched = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=4, growth_factor=2.0, plateau_tol=0.01,
        plateau_patience=1))
    sizes = []
    for _ in range(6):
        sizes.append(sched.cohort_size())
        sched.record_round(1.0)       # never improves => plateau every round
    # round 0 only sets the loss baseline; doubling starts at round 1
    assert sizes == [4, 4, 8, 16, 32, 64]
    sched.record_round(1.0)
    assert sched.cohort_size() == 64  # capped at the fleet size


def test_scheduler_improvement_defers_growth():
    specs = _specs(np.ones(16))
    sched = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=4, plateau_tol=0.01, plateau_patience=1))
    loss = 1.0
    for _ in range(4):
        sched.record_round(loss)
        loss *= 0.5                   # strong improvement every round
    assert sched.cohort_size() == 4
    assert sched.growth_log == []


def test_scheduler_observed_capability_reranks_and_rebudgets():
    specs = _specs([2.0, 1.0], m=100)
    sched = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=1, explore_frac=0.0, ewma=1.0))
    assert sched.select().tolist() == [0]
    # client 0 turns out to be 20x slower than nominal
    sched.observe(0, work_units=100.0, duration=1000.0)
    assert sched.select().tolist() == [1]
    # budget follows the observed capability, not the spec sheet
    b_nominal = coreset_budget(100, 2.0, deadline=100.0, epochs=3)
    assert b_nominal == 50
    b_observed = sched.budget(0, deadline=100.0, epochs=3)
    assert b_observed < b_nominal
    assert b_observed == coreset_budget(100, 0.1, 100.0, 3)


# ---------------------------------------------------------------------------
# scenarios through both runtimes, from one registry
# ---------------------------------------------------------------------------

SWEPT = ("uniform", "pareto", "flash_crowd", "device_classes")


def test_registry_has_named_regimes():
    assert set(SWEPT) <= set(SCENARIOS)
    assert len(SCENARIOS) >= 5
    sizes = [40] * 200
    for name in SCENARIOS:
        specs, trace = build_scenario(name, sizes, seed=0)
        caps = np.array([s.c for s in specs])
        assert (caps > 0).all()
        assert 0.3 < caps.mean() < 3.0   # mean-≈1 so deadlines compare
        specs2, _ = build_scenario(name, sizes, seed=0)
        assert [s.c for s in specs2] == [s.c for s in specs]


@pytest.mark.parametrize("name", SWEPT)
def test_scenario_sync_deterministic(fleet_fl, name):
    model, train, test, _ = fleet_fl

    def go():
        return run_scenario(name, "sync", model, train, seed=1, rounds=2,
                            clients_per_round=3, epochs=2, batch_size=8)

    def virtual(history):   # drop wall_time — the only real-clock field
        recs = [dataclasses.asdict(r) for r in history]
        for r in recs:
            r.pop("wall_time")
        return recs
    a, b = go(), go()
    assert virtual(a["history"]) == virtual(b["history"])
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", SWEPT)
def test_scenario_async_deterministic(fleet_fl, name):
    model, train, test, _ = fleet_fl

    def go():
        return run_scenario(name, "async", model, train, seed=1,
                            max_updates=6, clients_per_round=3,
                            concurrency=3, epochs=2, batch_size=8)
    a, b = go(), go()
    assert "\n".join(a["event_log"]).encode() == \
        "\n".join(b["event_log"]).encode()
    assert a["telemetry"]["makespan"] == b["telemetry"]["makespan"]


def test_scenarios_differ_from_each_other(fleet_fl):
    model, train, _, _ = fleet_fl
    logs = {}
    for name in ("uniform", "flash_crowd"):
        out = run_scenario(name, "async", model, train, seed=1,
                           max_updates=6, concurrency=3, epochs=2,
                           batch_size=8)
        logs[name] = out["event_log"]
    assert logs["uniform"] != logs["flash_crowd"]


def test_async_scheduler_restricts_dispatch(fleet_fl):
    model, train, _, specs = fleet_fl
    # ewma=0 freezes the ranking so the eligible set is constant all run
    sched = AdaptiveParticipation(specs, ParticipationConfig(
        min_cohort=4, explore_frac=0.0, plateau_tol=1.0,
        max_cohort=4, ewma=0.0))
    out = run_scenario("uniform", "async", model, train, seed=1,
                       max_updates=8, concurrency=4, epochs=2,
                       batch_size=8, scheduler=sched)
    eligible = set(np.flatnonzero(sched.eligible_mask()).tolist())
    dispatched = {int(line.split("cid=")[1].split(" ")[0])
                  for line in out["event_log"] if " dispatch " in line}
    assert dispatched <= eligible
    assert (sched._n_obs > 0).sum() > 0


def test_fleet_runtime_via_registry(fleet_fl):
    model, train, test, _ = fleet_fl
    out = run_scenario("device_classes", "fleet", model, train, test,
                       seed=0, rounds=2, epochs=2, batch_size=16)
    assert out["runtime"] == "fleet"
    assert len(out["history"]) == 2
    assert np.isfinite(out["history"][-1].test_acc)
