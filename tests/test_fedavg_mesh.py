"""shard_map FedAvg aggregation — validated on 8 forced host devices in a
subprocess (device count is locked at first jax init, so this test must not
pollute the main test process)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.fedavg_mesh import fedavg_allreduce

mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh:
    n = 4
    params = {"w": jnp.arange(float(n)).reshape(n, 1) * jnp.ones((n, 3)),
              "b": jnp.arange(float(n))}
    params = jax.tree.map(
        lambda x: jax.device_put(
            x, NamedSharding(mesh, P(("data",), *([None] * (x.ndim - 1))))),
        params)
    weights = jax.device_put(jnp.ones(n),
                             NamedSharding(mesh, P(("data",))))
    out = fedavg_allreduce(params, weights, mesh, client_axes=("data",))
    assert out["w"].shape == (3,)
    assert np.allclose(np.asarray(out["w"]), 1.5), out["w"]
    assert np.allclose(float(out["b"]), 1.5)
    # weighted
    weights = jax.device_put(jnp.array([1., 1., 1., 5.]),
                             NamedSharding(mesh, P(("data",))))
    out = fedavg_allreduce(params, weights, mesh, client_axes=("data",))
    assert np.allclose(np.asarray(out["w"]), 2.25), out["w"]
print("OK")
"""


def test_fedavg_mesh_aggregation():
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"},
                         cwd=__file__.rsplit("/tests/", 1)[0], timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
