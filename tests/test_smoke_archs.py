"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates the REDUCED variant of the arch family (2 layers,
d_model<=256, <=4 experts), runs one forward pass and one SGD train step on
CPU, and asserts output shapes + no NaNs; plus one decode step against the
family's cache/state machinery.  The FULL configs are exercised only via the
dry-run (launch/dryrun.py, ShapeDtypeStruct — no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model
from repro.models.training import make_train_step
from repro.optim.optimizers import sgd

SEQ = 32
BATCH = 2


def _make_batch(cfg, model, key=0):
    k = jax.random.PRNGKey(key)
    tl = model._text_len(SEQ)
    batch = {
        "tokens": jax.random.randint(k, (BATCH, tl), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (BATCH, tl), 0, cfg.vocab_size),
        "weights": jnp.ones((BATCH,), jnp.float32),
    }
    if cfg.family == "audio":
        batch["encoder_embeddings"] = jax.random.normal(
            k, (BATCH, SEQ - tl, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeddings"] = jax.random.normal(
            k, (BATCH, model._n_patches(SEQ), cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _make_batch(cfg, model)

    logits, aux, hidden = model.forward(params, batch)
    tl = model._text_len(SEQ)
    assert logits.shape == (BATCH, tl, cfg.vocab_size)
    assert hidden.shape == (BATCH, tl, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: NaN logits"

    opt = sgd(1e-2)
    step = make_train_step(model.loss, opt, donate=False)
    opt_state = opt.init(params)
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch_id}: NaN loss"
    # params actually changed
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved, f"{arch_id}: train step did not update params"
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch_id}: NaN params"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(params, BATCH, SEQ, dtype=jnp.float32)
    token = jnp.zeros((BATCH, 1), jnp.int32)
    logits, state = model.decode_step(params, state, token,
                                      jnp.asarray(3, jnp.int32))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: NaN decode"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch_id):
    from repro.configs.base import SHAPES
    cfg = get_config(arch_id)
    model = Model(cfg)
    for shape in SHAPES.values():
        specs = model.input_specs(shape)
        assert isinstance(specs, dict) and specs
        if shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch, 1)
        else:
            assert specs["tokens"].shape[0] == shape.global_batch
            total = specs["tokens"].shape[1]
            if cfg.family == "audio":
                total += specs["encoder_embeddings"].shape[1]
            if cfg.family == "vlm":
                total += specs["patch_embeddings"].shape[1]
            assert total == shape.seq_len
