"""Multi-device parity tests for the sharded fleet engine.

The sharded engine must match the batched engine bit-for-bit on medoid
choices and within float32 tolerance on aggregated params when cohort
groups are actually *split* across devices — padding lanes, per-device
k-medoids convergence, and the cross-device psum all engaged.  CPU hosts
expose multiple XLA devices only via ``--xla_force_host_platform_
device_count``, which must be set before jax initializes; when this test
process already has >= 4 devices (the CI multi-device job) the checks
run in-process, otherwise the module re-execs itself as a 4-device
subprocess and asserts on its report.
"""
import json
import os
import subprocess
import sys

from repro.utils.xla_env import forced_host_device_env

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DEVICES = 4


def _parity_payload():
    """Run sharded-vs-batched parity on this process's devices."""
    import jax
    import numpy as np

    from conftest import fleet_bundle
    from repro.fed.fleet.batched import (FleetConfig, FleetEngine,
                                         make_cohort_groups,
                                         nominal_budgets, run_fleet_round)
    from repro.fed.fleet.sharded import ShardedFleetEngine, client_mesh
    from repro.fed.simulator import straggler_deadline

    # 18 clients: group sizes won't divide the device count evenly, so
    # zero-weight padding lanes are exercised alongside real splits
    # (deduped builder from conftest, device_classes capabilities)
    b = fleet_bundle(workload="mlp", n_clients=18, seed=3,
                     scenario="device_classes")
    model, train, specs = b.model, b.train, b.specs
    cfg = FleetConfig(epochs=3, batch_size=16, lr=0.05, seed=0)
    deadline = straggler_deadline(specs, cfg.epochs, 40.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    params = model.init(jax.random.PRNGKey(0))
    cids = list(range(len(specs)))
    groups = make_cohort_groups(train, cids, budgets, cfg, round_seed=0)

    pb, sb = run_fleet_round(FleetEngine(model, cfg), params, train, cids,
                             budgets, round_seed=0, mode="batched",
                             groups=groups)
    eng = ShardedFleetEngine(model, cfg, mesh=client_mesh())
    ps, ss = run_fleet_round(eng, params, train, cids, budgets,
                             round_seed=0, mode="sharded", groups=groups)

    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree.leaves(pb), jax.tree.leaves(ps)))
    return {
        "n_devices": len(jax.devices()),
        "mesh_devices": int(eng.n_devices),
        "n_groups": len(groups),
        "n_coreset_clients": int(sb.used_coreset.sum()),
        "max_param_diff": diff,
        "losses_max_diff": float(np.max(np.abs(sb.losses - ss.losses))),
        "medoid_cids_equal": sorted(sb.medoids) == sorted(ss.medoids),
        "medoids_equal": bool(
            sorted(sb.medoids) == sorted(ss.medoids) and all(
                np.array_equal(sb.medoids[c], ss.medoids[c])
                for c in sb.medoids)),
    }


def _subprocess_payload():
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker"],
        env=forced_host_device_env(N_DEVICES, REPO),
        capture_output=True, text=True, timeout=600)
    line = next((ln for ln in proc.stdout.splitlines()
                 if ln.startswith("PARITY:")), None)
    assert proc.returncode == 0 and line is not None, \
        f"worker failed:\n{proc.stdout}\n{proc.stderr}"
    return json.loads(line[len("PARITY:"):])


def test_sharded_matches_batched_on_four_devices():
    import jax
    payload = (_parity_payload() if len(jax.devices()) >= N_DEVICES
               else _subprocess_payload())
    assert payload["n_devices"] >= N_DEVICES     # the mesh really split
    assert payload["mesh_devices"] >= N_DEVICES
    assert payload["n_coreset_clients"] > 0      # Alg. 1 path exercised
    assert payload["medoids_equal"]              # bit-identical choices
    assert payload["max_param_diff"] < 1e-5      # float32 sum-order tol
    assert payload["losses_max_diff"] < 1e-5


if __name__ == "__main__":
    if "--worker" in sys.argv:
        print("PARITY:" + json.dumps(_parity_payload()))
    else:
        print(json.dumps(_subprocess_payload(), indent=2))
