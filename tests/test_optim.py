"""Optimizer + schedule + training-step tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.training import make_train_step, prox_term
from repro.optim.optimizers import adam, adamw, clip_by_global_norm, sgd
from repro.optim.schedules import (constant_lr, cosine_lr, inverse_time_lr,
                                   warmup_cosine_lr)
from repro.utils.tree import tree_add


def _quadratic_loss(params, batch):
    loss = jnp.sum((params["w"] - 3.0) ** 2)
    return loss, {"loss": loss}


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adam(0.2), adamw(0.2, weight_decay=0.0)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: _quadratic_loss(p, None)[0])(params)
        updates, state = opt.update(grads, state, params)
        params = tree_add(params, updates)
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=1e-2)


def test_clip_by_global_norm():
    grads = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == 20.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_schedules():
    s = inverse_time_lr(2.0, 10.0)
    assert float(s(jnp.asarray(0))) == pytest.approx(0.2)
    assert float(s(jnp.asarray(10))) == pytest.approx(0.1)
    c = cosine_lr(1.0, 100)
    assert float(c(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1)
    w = warmup_cosine_lr(1.0, 10, 110)
    assert float(w(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(constant_lr(0.3)(jnp.asarray(7))) == pytest.approx(0.3)


def test_train_step_with_prox():
    opt = sgd(0.1)
    ref = {"w": jnp.zeros(4)}
    step = make_train_step(_quadratic_loss, opt, prox_mu=10.0, donate=False)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    for _ in range(200):
        params, state, _ = step(params, state, None, ref)
    # proximal pull keeps solution between 0 (ref) and 3 (minimizer):
    # grad: 2(w-3) + 10(w-0) = 0  ->  w = 0.5
    np.testing.assert_allclose(np.asarray(params["w"]), 0.5, atol=1e-2)


def test_prox_term_value():
    a = {"w": jnp.ones(4)}
    b = {"w": jnp.zeros(4)}
    assert float(prox_term(a, b)) == 4.0


def test_weighted_loss_zero_weight_examples_ignored():
    from repro.models.small import LogisticRegression
    model = LogisticRegression(n_features=4, n_classes=3)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.PRNGKey(1), x.shape),
        params)
    x = jax.random.normal(jax.random.PRNGKey(2), (6, 4))
    y = jnp.array([0, 1, 2, 0, 1, 2])
    full, _ = model.loss(params, {"x": x[:3], "y": y[:3]})
    w = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    masked, _ = model.loss(params, {"x": x, "y": y, "weights": w})
    np.testing.assert_allclose(float(full), float(masked), rtol=1e-6)
