"""Coreset construction + ε-approximation (Assumption A.3) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coreset import (build_coreset, coreset_batch, coreset_budget,
                                coreset_epsilon, needs_coreset)
from repro.core.gradients import grad_features, true_per_sample_grads
from repro.models.small import LogisticRegression, SmallCNN


def test_budget_formula():
    # b = floor((c*tau - m) / (E-1))  (§4.2)
    assert coreset_budget(m=100, capability=2.0, deadline=100.0,
                          epochs=6) == 20
    assert coreset_budget(m=100, capability=1.0, deadline=500.0,
                          epochs=5) == 100  # clipped at m
    assert coreset_budget(m=100, capability=0.1, deadline=10.0,
                          epochs=5) == 1   # floor at 1


def test_needs_coreset():
    assert not needs_coreset(m=10, capability=1.0, deadline=100.0, epochs=10)
    assert needs_coreset(m=100, capability=1.0, deadline=10.0, epochs=10)


def _logreg_client(seed=0, m=120, d=10, classes=4):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    w = rng.normal(size=(d, classes))
    y = np.argmax(x @ w, axis=1).astype(np.int32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def test_coreset_weights_sum_to_m():
    data = _logreg_client()
    model = LogisticRegression(n_features=10, n_classes=4)
    params = model.init(jax.random.PRNGKey(0))
    feats = grad_features(model, params, data)
    cs = build_coreset(feats, 12)
    assert int(np.sum(np.asarray(cs.weights))) == 120
    assert len(np.asarray(cs.indices)) == 12


def test_epsilon_decreases_with_budget():
    """The ε in Assumption A.3, measured on exact per-sample gradients,
    shrinks as the coreset budget grows (the paper's core premise)."""
    data = _logreg_client(m=90)
    model = LogisticRegression(n_features=10, n_classes=4)
    params = model.init(jax.random.PRNGKey(1))
    feats = grad_features(model, params, data)
    grads = true_per_sample_grads(model.loss, params, data)
    eps = []
    for b in (3, 10, 30, 90):
        cs = build_coreset(feats, b)
        eps.append(float(coreset_epsilon(jnp.asarray(grads), cs)))
    assert eps[-1] < 1e-6           # full-budget coreset is exact
    assert eps[0] > eps[2]          # monotone-ish improvement
    # coreset beats a random subset of the same size on average
    rng = np.random.default_rng(0)
    rand_eps = []
    for _ in range(5):
        idx = rng.choice(90, size=10, replace=False)
        approx = grads[idx].sum(0) * (90 / 10)
        rand_eps.append(np.linalg.norm(grads.sum(0) - approx) / 90)
    cs10 = build_coreset(feats, 10)
    assert float(coreset_epsilon(jnp.asarray(grads), cs10)) < np.mean(
        rand_eps) * 1.5


def test_coreset_batch_materialization():
    data = _logreg_client(m=40)
    model = LogisticRegression(n_features=10, n_classes=4)
    params = model.init(jax.random.PRNGKey(0))
    feats = grad_features(model, params, data)
    cs = build_coreset(feats, 8)
    cb = coreset_batch({k: np.asarray(v) for k, v in data.items()}, cs, 40)
    assert cb["x"].shape == (8, 10)
    assert cb["weights"].shape == (8,)
    assert float(np.sum(cb["weights"])) == 40.0


def test_last_layer_grad_proxy_correlates_with_true_distance():
    """§4.3: d̂ (last-layer proxy) should rank pairs like the true gradient
    distance d (rank correlation well above chance)."""
    data = _logreg_client(m=40, d=8, classes=3)
    model = SmallCNN(image_size=8, channels=(4, 8), n_classes=3)
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(40, 8, 8)).astype(np.float32)
    labels = (imgs.mean(axis=(1, 2)) > 0).astype(np.int32)
    d2 = {"x": jnp.asarray(imgs), "y": jnp.asarray(labels)}
    params = model.init(jax.random.PRNGKey(2))
    feats = np.asarray(grad_features(model, params, d2))
    grads = true_per_sample_grads(model.loss, params, d2, batch_size=40)

    def pdist(a):
        return np.linalg.norm(a[:, None] - a[None, :], axis=-1)

    dp = pdist(feats)[np.triu_indices(40, 1)]
    dt = pdist(grads)[np.triu_indices(40, 1)]
    rho = np.corrcoef(np.argsort(np.argsort(dp)),
                      np.argsort(np.argsort(dt)))[0, 1]
    assert rho > 0.5, f"rank correlation too weak: {rho}"


@settings(max_examples=10, deadline=None)
@given(m=st.integers(10, 60), budget=st.integers(2, 10))
def test_property_coreset_valid(m, budget):
    rng = np.random.default_rng(m * 100 + budget)
    feats = jnp.asarray(rng.normal(size=(m, 5)).astype(np.float32))
    cs = build_coreset(feats, budget)
    b = min(budget, m)
    idx = np.asarray(cs.indices)
    assert len(idx) == b
    assert len(set(idx.tolist())) == b
    assert int(np.asarray(cs.weights).sum()) == m
    assert float(cs.objective) >= -1e-6
