"""Async runtime tests: event queue ordering, aggregator math, staleness
discounting, determinism (byte-identical event logs / histories), and an
end-to-end FedCore smoke run."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.aggregators import (ClientUpdate, DelayedGradient, FedAsync,
                                   FedBuff, SyncWeightedMean,
                                   polynomial_staleness, weighted_mean_params)
from repro.fed.events import AsyncFLConfig, EventQueue, run_federated_async
from repro.fed.server import FLConfig, run_federated
from repro.fed.simulator import (CapabilityTrace, ClientSpec, TraceConfig,
                                 make_client_specs)
from repro.fed.strategies import FedAvg, FedAvgDS, FedCore, LocalTrainer
from repro.models.small import LogisticRegression


@pytest.fixture(scope="module")
def tiny_fl():
    clients = synthetic_dataset(0.5, 0.5, n_clients=8, mean_samples=80,
                                std_samples=50, seed=1)
    train, test = train_test_split_clients(clients)
    rng = np.random.default_rng(1)
    specs = make_client_specs([len(d["y"]) for d in train], rng)
    return LogisticRegression(), train, test, specs


def _async_cfg(**kw):
    base = dict(max_updates=20, concurrency=4, epochs=4, batch_size=8,
                lr=0.05, straggler_pct=30.0, record_every=5, seed=3,
                trace=TraceConfig(seed=3))
    base.update(kw)
    return AsyncFLConfig(**base)


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_push_order():
    q = EventQueue()
    q.push(5.0, "complete", cid=1, version=0)
    q.push(1.0, "dispatch", cid=2, version=0)
    q.push(1.0, "dispatch", cid=3, version=0)  # same time: push order wins
    order = [(q.pop().cid) for _ in range(3)]
    assert order == [2, 3, 1]
    assert len(q) == 0


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------

def test_polynomial_staleness():
    assert polynomial_staleness(0, 0.5) == 1.0
    assert polynomial_staleness(3, 0.5) == pytest.approx(0.5)
    assert polynomial_staleness(7, 1.0) == pytest.approx(1.0 / 8.0)


def test_weighted_mean_params_by_samples():
    trees = [{"w": jnp.ones(3)}, {"w": jnp.zeros(3)}]
    w = weighted_mean_params(trees, [300, 100], weight_by_samples=True)
    np.testing.assert_allclose(np.asarray(w["w"]), 0.75)
    u = weighted_mean_params(trees, [300, 100], weight_by_samples=False)
    np.testing.assert_allclose(np.asarray(u["w"]), 0.5)


def test_fedasync_staleness_discounted_mixing():
    agg = FedAsync(mixing=0.5, staleness_exponent=1.0)
    g = {"w": jnp.zeros(2)}
    upd = {"w": jnp.ones(2)}
    # staleness 0: alpha = 0.5
    out = agg.apply(g, ClientUpdate(upd, n_samples=10, staleness=0))
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5)
    # staleness 3: alpha = 0.5 * (1+3)^-1 = 0.125
    out = agg.apply(g, ClientUpdate(upd, n_samples=10, staleness=3))
    np.testing.assert_allclose(np.asarray(out["w"]), 0.125, rtol=1e-6)


def test_delayed_gradient_applies_discounted_delta():
    agg = DelayedGradient(server_lr=0.5, staleness_exponent=1.0)
    g = {"w": jnp.full((2,), 10.0)}
    base = {"w": jnp.zeros(2)}
    client = {"w": jnp.full((2,), 4.0)}
    # delta = 4, staleness 1 -> scale = 0.5 * 0.5 = 0.25 -> 10 + 1
    out = agg.apply(g, ClientUpdate(client, n_samples=5, staleness=1,
                                    base_params=base))
    np.testing.assert_allclose(np.asarray(out["w"]), 11.0)


def test_delayed_gradient_requires_base_params():
    agg = DelayedGradient()
    with pytest.raises(ValueError):
        agg.apply({"w": jnp.zeros(1)},
                  ClientUpdate({"w": jnp.ones(1)}, n_samples=1))


def test_fedbuff_buffers_then_applies_discounted_mean():
    agg = FedBuff(buffer_size=2, staleness_exponent=1.0, server_lr=1.0,
                  weight_by_samples=True)
    g = {"w": jnp.zeros(1)}
    first = agg.apply(g, ClientUpdate({"w": jnp.ones(1)}, n_samples=100,
                                      staleness=0))
    assert first is None  # buffered
    out = agg.apply(g, ClientUpdate({"w": jnp.full((1,), 3.0)}, n_samples=100,
                                    staleness=1))
    # weights: 100*1, 100*0.5 -> (1*100 + 3*50) / 150 = 5/3
    np.testing.assert_allclose(np.asarray(out["w"]), 5.0 / 3.0, rtol=1e-6)
    # buffer cleared: next apply buffers again
    assert agg.apply(g, ClientUpdate({"w": jnp.ones(1)}, 1, 0)) is None


def test_fedbuff_reset_discards_partial_buffer():
    agg = FedBuff(buffer_size=2)
    g = {"w": jnp.zeros(1)}
    assert agg.apply(g, ClientUpdate({"w": jnp.ones(1)}, 1, 0)) is None
    agg.reset()     # run boundary: leftover update must not leak
    assert agg.apply(g, ClientUpdate({"w": jnp.zeros(1)}, 1, 0)) is None
    out = agg.apply(g, ClientUpdate({"w": jnp.zeros(1)}, 1, 0))
    np.testing.assert_allclose(np.asarray(out["w"]), 0.0)


def test_fedbuff_server_lr_mixes_toward_global():
    agg = FedBuff(buffer_size=1, staleness_exponent=0.0, server_lr=0.5)
    g = {"w": jnp.zeros(1)}
    out = agg.apply(g, ClientUpdate({"w": jnp.full((1,), 2.0)}, 10, 0))
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)


def test_sync_weighted_mean_streaming_round():
    agg = SyncWeightedMean(weight_by_samples=True, round_size=2)
    g = {"w": jnp.zeros(1)}
    assert agg.apply(g, ClientUpdate({"w": jnp.ones(1)}, 30, 0)) is None
    out = agg.apply(g, ClientUpdate({"w": jnp.zeros(1)}, 10, 0))
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75)


def test_sync_weighted_mean_requires_round_size_for_streaming():
    agg = SyncWeightedMean()
    with pytest.raises(ValueError):
        agg.apply({"w": jnp.zeros(1)}, ClientUpdate({"w": jnp.ones(1)}, 1, 0))


# ---------------------------------------------------------------------------
# capability traces
# ---------------------------------------------------------------------------

def test_capability_trace_deterministic_and_order_free():
    cfg = TraceConfig(jitter_std=0.2, slowdown_prob=0.3, seed=7)
    spec = ClientSpec(cid=4, m=100, c=2.0)
    a, b = CapabilityTrace(cfg), CapabilityTrace(cfg)
    # query b out of order — trace must be a pure function of the index
    got_b = {k: (b.capability(spec, k), b.jitter(spec, k))
             for k in (5, 0, 3, 1, 4, 2)}
    for k in range(6):
        assert (a.capability(spec, k), a.jitter(spec, k)) == got_b[k]


def test_capability_trace_slowdowns_reduce_capability():
    cfg = TraceConfig(jitter_std=0.0, slowdown_prob=0.5, slowdown_factor=4.0,
                      seed=0)
    trace = CapabilityTrace(cfg)
    spec = ClientSpec(cid=0, m=10, c=8.0)
    caps = {trace.capability(spec, k) for k in range(64)}
    assert caps == {8.0, 2.0}  # both states visited; factor honored
    assert all(trace.jitter(spec, k) == 1.0 for k in range(8))


# ---------------------------------------------------------------------------
# async engine end-to-end
# ---------------------------------------------------------------------------

def test_async_determinism_byte_identical(tiny_fl):
    model, train, test, specs = tiny_fl
    cfg = _async_cfg()
    outs = []
    for _ in range(2):
        strat = FedAvg(LocalTrainer(model, cfg.lr, cfg.batch_size))
        outs.append(run_federated_async(model, train, specs, strat, cfg,
                                        aggregator=FedAsync(),
                                        test_data=test))
    a, b = outs
    assert "\n".join(a["event_log"]).encode() == \
        "\n".join(b["event_log"]).encode()
    assert [dataclasses.astuple(r) for r in a["history"]] == \
        [dataclasses.astuple(r) for r in b["history"]]
    assert a["telemetry"]["makespan"] == b["telemetry"]["makespan"]


def test_async_seed_changes_trace(tiny_fl):
    model, train, test, specs = tiny_fl
    logs = []
    for seed in (0, 1):
        cfg = _async_cfg(seed=seed, trace=TraceConfig(seed=seed))
        strat = FedAvg(LocalTrainer(model, cfg.lr, cfg.batch_size))
        out = run_federated_async(model, train, specs, strat, cfg,
                                  aggregator=FedAsync())
        logs.append(out["event_log"])
    assert logs[0] != logs[1]


def test_async_respects_concurrency_cap(tiny_fl):
    model, train, test, specs = tiny_fl
    cfg = _async_cfg(concurrency=2)
    strat = FedAvg(LocalTrainer(model, cfg.lr, cfg.batch_size))
    out = run_federated_async(model, train, specs, strat, cfg,
                              aggregator=FedAsync())
    in_flight = 0
    for line in out["event_log"]:
        if " dispatch " in line:
            in_flight += 1
        else:
            in_flight -= 1
        assert in_flight <= 2


def test_async_fedcore_smoke_converges_and_reports(tiny_fl):
    model, train, test, specs = tiny_fl
    cfg = _async_cfg(max_updates=30, epochs=5)
    strat = FedCore(LocalTrainer(model, cfg.lr, cfg.batch_size))
    out = run_federated_async(model, train, specs, strat, cfg,
                              aggregator=FedAsync(mixing=0.6),
                              test_data=test)
    assert len(out["history"]) == 30 // cfg.record_every
    assert out["history"][-1].test_acc > 0.5
    assert sum(r.n_coreset for r in out["history"]) > 0  # coresets used
    t = out["telemetry"]
    assert t["n_updates_applied"] == 30
    assert t["makespan"] > 0
    assert 0.0 < t["client_utilization"] <= 1.0
    assert t["staleness_hist"].sum() == 30
    assert t["n_dispatches"] >= 30


def test_async_dropped_stragglers_block_slot_until_deadline(tiny_fl):
    model, train, test, specs = tiny_fl
    # FedAvgDS under async: stragglers return None and hold their slot for τ
    cfg = _async_cfg(max_updates=15)
    strat = FedAvgDS(LocalTrainer(model, cfg.lr, cfg.batch_size))
    out = run_federated_async(model, train, specs, strat, cfg,
                              aggregator=FedAsync())
    assert out["telemetry"]["n_dropped"] > 0
    tau = out["deadline"]
    drops = [l for l in out["event_log"]
             if " complete " in l and f"dur={tau!r}" in l]
    assert len(drops) >= out["telemetry"]["n_dropped"]


def test_async_terminates_when_no_client_can_finish(tiny_fl):
    model, train, test, specs = tiny_fl
    # deadline below every client's round time: FedAvgDS drops everyone,
    # no update is ever applied — the dispatch cap must end the run
    cfg = _async_cfg(max_updates=5, deadline=1e-6, max_dispatches=30)
    strat = FedAvgDS(LocalTrainer(model, cfg.lr, cfg.batch_size))
    out = run_federated_async(model, train, specs, strat, cfg,
                              aggregator=FedAsync())
    t = out["telemetry"]
    assert t["n_updates_applied"] == 0
    assert t["n_dropped"] > 0
    assert t["n_dispatches"] <= 30
    assert out["history"][-1].n_dropped > 0  # tail record captures drops


# ---------------------------------------------------------------------------
# sync server: weight_by_samples routing
# ---------------------------------------------------------------------------

def test_run_federated_weight_by_samples_changes_aggregate(tiny_fl):
    model, train, test, specs = tiny_fl
    outs = {}
    for wbs in (True, False):
        cfg = FLConfig(rounds=2, clients_per_round=4, epochs=2, batch_size=8,
                       lr=0.05, seed=0, weight_by_samples=wbs)
        strat = FedAvg(LocalTrainer(model, cfg.lr, cfg.batch_size))
        outs[wbs] = run_federated(model, train, specs, strat, cfg)
    w_t = np.asarray(outs[True]["params"]["w"])
    w_f = np.asarray(outs[False]["params"]["w"])
    assert not np.allclose(w_t, w_f)


def test_async_violations_in_history_and_telemetry(tiny_fl):
    model, train, test, specs = tiny_fl
    # impossible deadline: every FedCore update runs the minimal plan and
    # overruns τ — flagged per record and in the telemetry total
    cfg = _async_cfg(max_updates=10, deadline=1e-3)
    strat = FedCore(LocalTrainer(model, cfg.lr, cfg.batch_size))
    out = run_federated_async(model, train, specs, strat, cfg,
                              aggregator=FedAsync())
    t = out["telemetry"]
    assert t["n_violations"] == t["n_updates_applied"] == 10
    assert sum(r.n_violations for r in out["history"]) == 10
