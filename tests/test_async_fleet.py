"""Async fleet engine tests: merge-rule closed forms vs the streaming
aggregators, event-log determinism goldens, batched/loop/sharded parity,
partial-buffer flushes (engine and events.py tail drain), refcounted
dispatch snapshots, and dispatch-count scaling (groups, not clients)."""
import numpy as np
import pytest

from repro.fed.aggregators import (ClientUpdate, DelayedGradient, FedAsync,
                                   FedBuff)
from repro.fed.fleet.async_engine import (ASYNC_MERGES, AsyncFleetConfig,
                                          DelayedGradientMerge,
                                          FedAsyncMerge, FedBuffMerge,
                                          as_merge_rule, run_async_fleet)
from repro.fed.simulator import TraceConfig

from conftest import fleet_bundle

CFG = dict(max_updates=3, buffer_k=4, concurrency=8, epochs=2, batch_size=8,
           lr=0.05, straggler_pct=40.0, seed=0)


@pytest.fixture(scope="module")
def bundle():
    return fleet_bundle(workload="mlp", n_clients=12, seed=3,
                        mean_samples=40.0, std_samples=20.0,
                        scenario="device_classes")


def _run(bundle, engine="batched", **kw):
    cfg = AsyncFleetConfig(**{**CFG, "trace": bundle.trace, **kw})
    return run_async_fleet(bundle.workload, bundle.train, bundle.specs, cfg,
                           test_data=bundle.test, engine=engine)


def _leaves(params):
    import jax
    return [np.asarray(x) for x in jax.tree.leaves(params)]


# ---------------------------------------------------------------------------
# merge rules: vectorized flushes reproduce the streaming aggregators
# ---------------------------------------------------------------------------

def _toy_buffer(rng, k=5):
    """K fake client param vectors + staleness/sample metadata."""
    updates = [{"w": rng.normal(size=4).astype(np.float32)} for _ in range(k)]
    staleness = rng.integers(0, 4, size=k)
    n_samples = rng.integers(10, 50, size=k)
    g = {"w": rng.normal(size=4).astype(np.float32)}
    return g, updates, staleness, n_samples


def _flush(rule, g, updates, staleness, n_samples, bases=None):
    """Evaluate new = c_w*g + sum c_i*w_i (or the delta form) in float64,
    exactly the linear combination the engine's group programs compute."""
    c, c_w = rule.coefficients(np.asarray(staleness), np.asarray(n_samples))
    if rule.use_base:
        acc = sum(ci * (u["w"].astype(np.float64) - b["w"].astype(np.float64))
                  for ci, u, b in zip(c, updates, bases))
        return g["w"].astype(np.float64) * c_w + acc
    acc = sum(ci * u["w"].astype(np.float64) for ci, u in zip(c, updates))
    return g["w"].astype(np.float64) * c_w + acc


def test_fedasync_merge_closed_form_matches_sequential():
    """One FedAsyncMerge flush of K updates == K sequential FedAsync.apply
    calls with the same staleness values (the telescoped product form)."""
    rng = np.random.default_rng(0)
    g, updates, staleness, n_samples = _toy_buffer(rng)
    rule = FedAsyncMerge(mixing=0.6, staleness_exponent=0.5)
    got = _flush(rule, g, updates, staleness, n_samples)

    agg = FedAsync(mixing=0.6, staleness_exponent=0.5)
    seq = g
    for u, s, m in zip(updates, staleness, n_samples):
        seq = agg.apply(seq, ClientUpdate(u, n_samples=int(m),
                                          staleness=int(s)))
    np.testing.assert_allclose(got, np.asarray(seq["w"]), atol=1e-6)


@pytest.mark.parametrize("weight_by_samples", (False, True))
@pytest.mark.parametrize("server_lr", (1.0, 0.7))
def test_fedbuff_merge_matches_streaming(server_lr, weight_by_samples):
    """FedBuffMerge coefficients == FedBuff._merge on the same buffer."""
    rng = np.random.default_rng(1)
    g, updates, staleness, n_samples = _toy_buffer(rng)
    rule = FedBuffMerge(staleness_exponent=0.5, server_lr=server_lr,
                        weight_by_samples=weight_by_samples)
    got = _flush(rule, g, updates, staleness, n_samples)

    agg = FedBuff(buffer_size=len(updates), staleness_exponent=0.5,
                  server_lr=server_lr, weight_by_samples=weight_by_samples)
    buf = [ClientUpdate(u, n_samples=int(m), staleness=int(s))
           for u, s, m in zip(updates, staleness, n_samples)]
    ref = agg._merge(buf, g)
    np.testing.assert_allclose(got, np.asarray(ref["w"]), atol=1e-6)


def test_delayed_gradient_merge_matches_sequential():
    """DelayedGradientMerge == sequential DelayedGradient.apply: the delta
    form is order-independent, so one vectorized flush is exact."""
    rng = np.random.default_rng(2)
    g, updates, staleness, n_samples = _toy_buffer(rng)
    bases = [{"w": rng.normal(size=4).astype(np.float32)} for _ in updates]
    rule = DelayedGradientMerge(server_lr=0.8, staleness_exponent=0.5)
    got = _flush(rule, g, updates, staleness, n_samples, bases=bases)

    agg = DelayedGradient(server_lr=0.8, staleness_exponent=0.5)
    seq = g
    for u, b, s, m in zip(updates, bases, staleness, n_samples):
        seq = agg.apply(seq, ClientUpdate(u, n_samples=int(m),
                                          staleness=int(s), base_params=b))
    np.testing.assert_allclose(got, np.asarray(seq["w"]), atol=1e-6)


def test_as_merge_rule_coercion():
    assert isinstance(as_merge_rule(None), FedBuffMerge)
    for name, factory in ASYNC_MERGES.items():
        # robust-method entries are functools.partial(RobustMerge, method)
        cls = getattr(factory, "func", factory)
        rule = as_merge_rule(name)
        assert isinstance(rule, cls)
        assert rule.name == name
    rule = as_merge_rule(FedAsync(mixing=0.3, staleness_exponent=1.0))
    assert isinstance(rule, FedAsyncMerge)
    assert rule.mixing == 0.3 and rule.staleness_exponent == 1.0
    rule = as_merge_rule(FedBuff(server_lr=0.5, weight_by_samples=True))
    assert isinstance(rule, FedBuffMerge)
    assert rule.server_lr == 0.5 and rule.weight_by_samples
    with pytest.raises(ValueError, match="unknown async merge rule"):
        as_merge_rule("fedsync")
    with pytest.raises(TypeError):
        as_merge_rule(object())


# ---------------------------------------------------------------------------
# engine determinism + parity
# ---------------------------------------------------------------------------

def test_event_log_determinism_golden(bundle):
    """Two identical runs: byte-identical event logs, histories, params."""
    a, b = _run(bundle), _run(bundle)
    assert a["event_log"] == b["event_log"]
    assert len(a["event_log"]) > 0
    assert [r.__dict__ for r in a["history"]] == \
        [r.__dict__ for r in b["history"]]
    for x, y in zip(_leaves(a["params"]), _leaves(b["params"])):
        assert x.tobytes() == y.tobytes()


def test_engine_mode_parity(bundle):
    """The determinism contract: the event schedule is a pure function of
    (seed, specs, trace, scheduler), so grouping/execution mode changes
    nothing about it — and batched==loop params agree bit-for-bit on
    mlp (one fused scan on both sides)."""
    outs = {e: _run(bundle, engine=e) for e in ("batched", "loop", "sharded")}
    assert outs["batched"]["event_log"] == outs["loop"]["event_log"]
    assert outs["batched"]["event_log"] == outs["sharded"]["event_log"]
    for x, y in zip(_leaves(outs["batched"]["params"]),
                    _leaves(outs["loop"]["params"])):
        np.testing.assert_allclose(x, y, atol=1e-5)
    # single-host: sharded transparently falls back to batched
    import jax
    if len(jax.devices()) == 1:
        assert outs["sharded"]["engine_mode"] == "batched"


def test_dispatch_scales_with_groups(bundle):
    """Micro-batching's point: jitted group-program dispatches track the
    number of distinct (M, k) shapes per flush, not the client count."""
    out = _run(bundle)
    tel = out["telemetry"]
    assert tel["n_dispatches"] >= CFG["buffer_k"] * CFG["max_updates"]
    assert 0 < tel["n_group_dispatches"] < tel["n_dispatches"]
    assert tel["n_merged_clients"] == CFG["buffer_k"] * CFG["max_updates"]
    assert tel["mean_buffer_occupancy"] > 0


def test_merge_rules_end_to_end(bundle):
    """Every registered merge rule drives the engine to completion and
    stamps its name on the run."""
    for name in ASYNC_MERGES:
        out = run_async_fleet(
            bundle.workload, bundle.train, bundle.specs,
            AsyncFleetConfig(**{**CFG, "max_updates": 2,
                                "trace": bundle.trace}),
            aggregator=name, test_data=bundle.test)
        assert out["aggregator"] == name
        assert out["applied"] == 2
        assert np.isfinite(out["history"][-1].train_loss)


# ---------------------------------------------------------------------------
# partial flushes
# ---------------------------------------------------------------------------

def test_engine_partial_flush_at_cutoff(bundle):
    """A max_virtual_time cutoff with a partly-filled buffer: the tail is
    merged as a partial flush instead of dropped."""
    full = _run(bundle)
    cut = full["telemetry"]["makespan"] * 0.45
    out = _run(bundle, max_virtual_time=cut)
    tel = out["telemetry"]
    assert tel["makespan"] <= cut
    assert out["applied"] >= 1
    if tel["n_partial_flushes"]:
        # the partial flush merged fewer than K clients
        assert tel["n_merged_clients"] < out["applied"] * CFG["buffer_k"]
        assert out["history"][-1].n_participants < CFG["buffer_k"]


def test_engine_partial_flush_forced(bundle):
    """buffer_k larger than what ever completes before the cutoff =>
    exactly one partial flush carries all the work."""
    out = _run(bundle, buffer_k=8, concurrency=8, max_updates=5,
               max_virtual_time=_run(bundle)["telemetry"]["makespan"] * 0.3)
    tel = out["telemetry"]
    if out["applied"]:
        assert tel["n_partial_flushes"] >= 1
        assert tel["n_merged_clients"] >= 1


def test_fedbuff_flush_unit():
    g = {"w": np.zeros(2, np.float32)}
    agg = FedBuff(buffer_size=3)
    assert agg.flush(g) is None                      # nothing buffered
    assert agg.apply(g, ClientUpdate({"w": np.ones(2, np.float32)},
                                     n_samples=5)) is None
    out = agg.flush(g)                               # partial: 1 of 3
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)
    assert agg.flush(g) is None                      # buffer now empty


def test_events_runtime_tail_drain(bundle):
    """run_federated_async + FedBuff with a buffer that never fills: the
    final drain applies the tail instead of discarding client work."""
    from repro.fed.events import AsyncFLConfig, run_federated_async
    from repro.fed.strategies import FedCore, LocalTrainer

    cfg = AsyncFLConfig(max_updates=50, max_dispatches=12, concurrency=4,
                        epochs=2, batch_size=8, lr=0.05, straggler_pct=40.0,
                        record_every=5, seed=0, trace=bundle.trace)
    strat = FedCore(LocalTrainer(bundle.workload, cfg.lr, cfg.batch_size))
    agg = FedBuff(buffer_size=100)   # can never fill in 12 dispatches
    out = run_federated_async(bundle.workload, bundle.train, bundle.specs,
                              strat, cfg, aggregator=agg,
                              test_data=bundle.test)
    # every applied update came from the tail drain
    assert out["telemetry"]["n_updates_applied"] == 1
    assert out["version"] == 1
