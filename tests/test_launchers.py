"""Smoke tests for the train/serve launchers (in-process, tiny presets)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import generate
from repro.launch.train import (PRESETS, synthetic_stream,
                                train_centralized, train_fedcore_lm)
from repro.models.model import Model


def test_synthetic_stream_shapes():
    gen = synthetic_stream(vocab=64, batch=4, seq=16, seed=0)
    b = next(gen)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    # next-token alignment
    b2 = next(gen)
    assert int(b2["tokens"].max()) < 64


def test_train_centralized_reduces_loss(tmp_path):
    cfg = PRESETS["tiny"]
    out = train_centralized(cfg, steps=12, batch=8, seq=64, lr=1e-3,
                            ckpt_dir=str(tmp_path), log_every=100, seed=0)
    assert out["final_loss"] < out["initial_loss"]
    import os
    assert any(f.endswith(".npz") for f in os.listdir(tmp_path))


def test_train_fedcore_lm_meets_deadline():
    cfg = PRESETS["tiny"]
    out = train_fedcore_lm(cfg, rounds=1, steps_per_epoch=3, silos=3,
                           batch=4, seq=32, lr=1e-3, straggler_pct=34.0,
                           seed=0)
    h = out["history"][0]
    assert h["round_time"] <= h["tau"] * 1.001
    assert h["coreset_silos"] >= 1


def test_generate_prefill_decode_consistency():
    cfg = PRESETS["tiny"]
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    out = generate(model, params, prompts, gen=5, temperature=0.0)
    assert out.shape == (2, 11)
    # greedy decode must be deterministic
    out2 = generate(model, params, prompts, gen=5, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    # and must agree with the forward-pass argmax for the first new token
    logits, _, _ = model.forward(params, {"tokens": prompts}, impl="naive")
    first_greedy = int(jnp.argmax(logits[0, -1]))
    assert int(out[0, 6]) == first_greedy
