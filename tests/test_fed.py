"""Federated runtime tests: strategies, deadlines, aggregation, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.server import FLConfig, run_federated, sample_clients, summarize
from repro.fed.simulator import (ClientSpec, make_client_specs,
                                 straggler_deadline, straggler_mask)
from repro.fed.strategies import (FedAvg, FedAvgDS, FedCore, FedProx,
                                  LocalTrainer)
from repro.models.small import LogisticRegression
from repro.utils.tree import tree_weighted_mean


@pytest.fixture(scope="module")
def small_fl():
    clients = synthetic_dataset(0.5, 0.5, n_clients=8, mean_samples=100,
                                std_samples=60, seed=1)
    train, test = train_test_split_clients(clients)
    rng = np.random.default_rng(1)
    specs = make_client_specs([len(d["y"]) for d in train], rng)
    model = LogisticRegression()
    cfg = FLConfig(rounds=5, clients_per_round=4, epochs=5, batch_size=8,
                   lr=0.05, straggler_pct=30.0, seed=1, eval_every=5)
    return model, train, test, specs, cfg


def test_deadline_marks_expected_straggler_fraction():
    rng = np.random.default_rng(0)
    specs = make_client_specs(rng.integers(50, 500, size=200), rng)
    for pct in (10.0, 30.0):
        tau = straggler_deadline(specs, epochs=10, straggler_pct=pct)
        frac = straggler_mask(specs, 10, tau).mean()
        assert abs(frac - pct / 100) < 0.05


def test_sampling_proportional_to_size():
    specs = [ClientSpec(0, 100, 1.0), ClientSpec(1, 900, 1.0)]
    rng = np.random.default_rng(0)
    picks = [c for _ in range(500) for c in sample_clients(specs, 2, rng)]
    frac1 = np.mean([p == 1 for p in picks])
    assert 0.82 < frac1 < 0.97


def test_aggregation_weighted_mean():
    trees = [{"w": jnp.ones(3)}, {"w": jnp.zeros(3)}]
    out = tree_weighted_mean(trees, [3.0, 1.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75)


def test_deadline_aware_strategies_respect_tau(small_fl):
    model, train, test, specs, cfg = small_fl
    for make in (lambda t: FedAvgDS(t), lambda t: FedCore(t),
                 lambda t: FedProx(t)):
        trainer = LocalTrainer(model, cfg.lr, cfg.batch_size,
                               prox_mu=0.1 if make.__name__ else 0.0)
        out = run_federated(model, train, specs, make(trainer), cfg)
        for rec in out["history"]:
            assert rec.sim_round_time <= out["deadline"] * 1.001, \
                f"{out['strategy']} exceeded deadline"


def test_fedavg_exceeds_deadline(small_fl):
    model, train, test, specs, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    out = run_federated(model, train, specs, FedAvg(trainer), cfg)
    times = [r.sim_round_time for r in out["history"]]
    assert max(times) > out["deadline"]  # oblivious to τ


def test_fedcore_uses_coresets_for_stragglers(small_fl):
    model, train, test, specs, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    out = run_federated(model, train, specs, FedCore(trainer), cfg)
    assert sum(r.n_coreset for r in out["history"]) > 0


def test_fedcore_converges(small_fl):
    model, train, test, specs, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    out = run_federated(model, train, specs, FedCore(trainer), cfg, test)
    s = summarize(out["history"], out["deadline"])
    assert s["final_test_acc"] > 0.5
    assert s["final_train_loss"] < 1.5


def test_fedavg_ds_drops_stragglers(small_fl):
    model, train, test, specs, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    out = run_federated(model, train, specs, FedAvgDS(trainer), cfg)
    assert sum(r.n_dropped for r in out["history"]) > 0


def test_fedprox_sub_batch_overrun_reports_true_time(small_fl):
    """A FedProx client whose budget cⁱτ is smaller than one batch still
    trains one clamped batch — it must report the true (over-deadline)
    duration and flag the violation, not a time clamped to τ."""
    model, train, _, _, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size, prox_mu=0.1)
    strat = FedProx(trainer)
    data = train[0]
    m = len(data["y"])
    deadline = 4.0
    spec = ClientSpec(cid=0, m=m, c=1.0)   # cτ = 4 < batch_size = 8
    res = strat.local_update(model.init(jax.random.PRNGKey(0)), data, spec,
                             deadline, cfg.epochs, np.random.default_rng(0))
    true_t = cfg.batch_size / spec.c       # one batch at capability c
    assert res.sim_time == pytest.approx(true_t)
    assert res.sim_time > deadline
    assert res.deadline_violated

    # a straggler whose clamped plan *fits* still reports in-deadline time
    spec_ok = ClientSpec(cid=1, m=m, c=float(m))  # cτ >> B but < E·m
    assert spec_ok.full_round_time(cfg.epochs) > deadline
    res_ok = strat.local_update(model.init(jax.random.PRNGKey(0)), data,
                                spec_ok, deadline, cfg.epochs,
                                np.random.default_rng(0))
    assert res_ok.sim_time <= deadline * (1.0 + 1e-9)
    assert not res_ok.deadline_violated


def test_fedcore_infeasible_client_is_surfaced(small_fl):
    """A client with cⁱτ below even the §4.4 minimal plan must not silently
    pretend to meet τ: the result is flagged (or dropped when opted in)."""
    from repro.core.coreset import FedCoreConfig

    model, train, _, _, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    data = train[0]
    m = len(data["y"])
    # capability*deadline << m/3: even forward-only + 1-sample coreset
    # overruns the deadline
    spec = ClientSpec(cid=0, m=m, c=0.1)
    deadline = 1.0
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))

    res = FedCore(trainer).local_update(params, data, spec, deadline,
                                        epochs=5, rng=rng)
    assert res is not None
    assert res.deadline_violated
    assert res.used_coreset and res.coreset_size == 1
    assert res.sim_time > deadline        # honest accounting, not clamped

    dropping = FedCore(trainer, FedCoreConfig(drop_infeasible=True))
    assert dropping.local_update(params, data, spec, deadline, epochs=5,
                                 rng=rng) is None


def test_fedcore_feasible_fallback_not_flagged(small_fl):
    """The §4.4 fallback that *does* fit in τ must not be flagged."""
    model, train, _, _, cfg = small_fl
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    data = train[0]
    m = len(data["y"])
    # cτ < m blocks the full first epoch, but leaves room for the
    # forward pass plus a real coreset budget
    spec = ClientSpec(cid=0, m=m, c=float(0.8 * m))
    res = FedCore(trainer).local_update(
        model.init(jax.random.PRNGKey(0)), data, spec, deadline=1.0,
        epochs=5, rng=np.random.default_rng(0))
    assert res is not None and res.used_coreset
    assert not res.deadline_violated
    assert res.sim_time <= 1.0 + 1e-9


def test_run_federated_counts_violations(small_fl):
    model, train, _, specs, _ = small_fl
    cfg = FLConfig(rounds=2, clients_per_round=4, epochs=5, batch_size=8,
                   lr=0.05, deadline=1e-3, seed=0)   # impossible deadline
    trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
    out = run_federated(model, train, specs, FedCore(trainer), cfg)
    assert all(r.n_violations == r.n_participants for r in out["history"])
