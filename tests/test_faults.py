"""Fault injection: dropout / churn / Byzantine corruption / label skew,
robust aggregation, and checkpoint/resume byte-identity.

The load-bearing regression here is **trace alignment**: injecting a
fault must never perturb the per-(client, dispatch-ordinal) capability
and jitter draws of surviving clients.  A dropped client's dispatch is
still recorded in ``DispatchTraceIndexer``, so every other client's
stream is byte-identical with the fault-free run.
"""
import dataclasses
import re

import jax
import numpy as np
import pytest

from conftest import fleet_bundle
from repro.fed.aggregators import (AGGREGATORS, ROBUST_METHODS,
                                   weighted_mean_params)
from repro.fed.fleet.async_engine import AsyncFleetConfig, run_async_fleet
from repro.fed.fleet.batched import FleetConfig, run_fleet
from repro.fed.fleet.faults import (FAULT_PROFILES, FaultProfile, FaultTrace,
                                    corrupt_stacked, dirichlet_label_skew,
                                    get_fault_profile)
from repro.fed.fleet.scheduler import AdaptiveParticipation
from repro.fed.fleet.scenarios import run_scenario


def _same_tree(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# fault primitives
# ---------------------------------------------------------------------------

def test_profile_registry_and_validation():
    assert "none" in FAULT_PROFILES and "hostile" in FAULT_PROFILES
    assert get_fault_profile(None) is None
    assert get_fault_profile("dropout").has_dropout
    assert not FAULT_PROFILES["none"].any_faults()
    with pytest.raises(ValueError):
        get_fault_profile("not_a_profile")
    with pytest.raises(ValueError):
        FaultProfile(name="bad", corrupt_mode="exotic", corrupt_frac=0.1)


def test_fault_trace_deterministic():
    p = FAULT_PROFILES["hostile"]
    a = FaultTrace(p, 40, seed=7)
    b = FaultTrace(p, 40, seed=7)
    assert np.array_equal(a.byzantine, b.byzantine)
    draws_a = [a.dropped(cid, k) for cid in range(40) for k in range(5)]
    draws_b = [b.dropped(cid, k) for cid in range(40) for k in range(5)]
    assert draws_a == draws_b
    for t in range(6):
        assert np.array_equal(a.present_mask(t), b.present_mask(t))
    # out-of-order queries hit the same per-ordinal streams
    c = FaultTrace(p, 40, seed=7)
    assert c.dropped(3, 4) == a.dropped(3, 4)
    assert c.dropped(3, 0) == a.dropped(3, 0)


def test_fault_trace_seed_changes_draws():
    p = FAULT_PROFILES["byzantine_signflip"]
    a, b = FaultTrace(p, 64, seed=0), FaultTrace(p, 64, seed=1)
    assert not np.array_equal(a.byzantine, b.byzantine)


def test_corrupt_stacked_leaves_honest_lanes_untouched():
    p = FAULT_PROFILES["byzantine_signflip"]
    tr = FaultTrace(p, 12, seed=0)
    rng = np.random.default_rng(0)
    base = {"w": rng.normal(size=(4, 3)).astype(np.float32)}
    stack = {"w": rng.normal(size=(12, 4, 3)).astype(np.float32)}
    out, n = corrupt_stacked(stack, base, np.arange(12),
                             np.zeros(12, np.int64), tr)
    byz = tr.byzantine
    assert n == int(byz.sum()) > 0
    for i in range(12):
        lane = np.asarray(out["w"][i])
        if byz[i]:          # sign flip: base − (p − base) = 2·base − p
            np.testing.assert_allclose(
                lane, 2.0 * base["w"] - stack["w"][i], rtol=1e-6)
        else:               # honest lanes bitwise identical
            assert np.array_equal(lane, stack["w"][i])


def test_churn_step_counts_transitions():
    p = FAULT_PROFILES["churn"]
    tr = FaultTrace(p, 100, seed=3)
    masks = [tr.churn_step(t) for t in range(8)]
    assert all(m.dtype == bool for m, _, _ in masks)
    # transitions are consistent with the reported join/leave counts
    for t in range(1, 8):
        prev, (cur, joins, leaves) = masks[t - 1][0], masks[t]
        assert joins == int((cur & ~prev).sum())
        assert leaves == int((prev & ~cur).sum())
    assert any(j or l for _, j, l in masks[1:])


def test_dirichlet_label_skew_preserves_sizes_and_skews():
    rng = np.random.default_rng(0)
    clients = [{"x": rng.normal(size=(40, 3)).astype(np.float32),
                "y": rng.integers(0, 8, 40)} for _ in range(10)]
    skewed = dirichlet_label_skew(clients, alpha=0.2, seed=1)
    assert [len(c["y"]) for c in skewed] == [len(c["y"]) for c in clients]

    def concentration(cs):
        # mean max-class share per client: higher = more skewed
        return float(np.mean([np.bincount(c["y"], minlength=8).max()
                              / len(c["y"]) for c in cs]))
    assert concentration(skewed) > concentration(clients) + 0.1
    # a repartition of the pooled data: same total size, no new classes
    # (exact multiset equality does not hold — drained class pools fall
    # back to with-replacement resampling)
    all_before = np.concatenate([c["y"] for c in clients])
    all_after = np.concatenate([c["y"] for c in skewed])
    assert all_after.size == all_before.size
    assert set(np.unique(all_after)) <= set(np.unique(all_before))
    # and it is deterministic in the seed
    again = dirichlet_label_skew(clients, alpha=0.2, seed=1)
    assert all(np.array_equal(a["y"], b["y"]) for a, b in zip(skewed, again))


# ---------------------------------------------------------------------------
# trace alignment: a dropped dispatch is still a recorded dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bundle():
    return fleet_bundle("mlp", n_clients=20)


def test_fleet_dropout_keeps_survivor_draws(bundle):
    b = bundle
    cfg = FleetConfig(epochs=1, batch_size=8, seed=0)
    clean = run_fleet(b.model, b.train, b.specs, cfg, rounds=3,
                      test_data=b.test)
    faulty = run_fleet(b.model, b.train, b.specs, cfg, rounds=3,
                       test_data=b.test, faults="dropout")
    assert sum(h.n_dropped for h in faulty["history"]) > 0
    # identical cohorts, identical per-client durations: the dropout
    # draw consumed no shared randomness and the dispatch-trace cursors
    # advanced exactly as in the clean run
    for hc, hf in zip(clean["history"], faulty["history"]):
        assert hc.client_times == hf.client_times


def test_events_dropout_keeps_per_dispatch_draws(bundle):
    b = bundle
    kw = dict(model=b.model, clients_data=b.train, test_data=b.test,
              rounds=3, clients_per_round=6, epochs=1, batch_size=8)
    clean = run_scenario("uniform", "async", **kw)
    faulty = run_scenario("uniform", "async", faults="dropout", **kw)
    assert faulty["telemetry"]["n_dropped"] > 0

    def durs(log):
        out = {}
        for line in log:
            m = re.match(r"t=.* COMPLETE cid=(\d+) v=\d+ dur=(.*)$", line)
            if m:
                out.setdefault(int(m.group(1)), []).append(m.group(2))
        return out
    a, c = durs(clean["event_log"]), durs(faulty["event_log"])
    # the k-th dispatch of any client realizes the same duration in both
    # runs (schedules diverge *after* a drop delays a flush, but the
    # per-(cid, ordinal) streams are pinned)
    for cid, seq in c.items():
        ref = a.get(cid, [])
        k = min(len(ref), len(seq))
        assert seq[:k] == ref[:k]


def test_async_fleet_dropout_keeps_per_dispatch_draws(bundle):
    b = bundle
    cfg = AsyncFleetConfig(max_updates=4, buffer_k=5, concurrency=10,
                           epochs=1, batch_size=8, seed=0)
    clean = run_async_fleet(b.model, b.train, b.specs, cfg, test_data=b.test)
    faulty = run_async_fleet(b.model, b.train, b.specs, cfg,
                             test_data=b.test, faults="dropout")
    assert faulty["telemetry"]["n_dropped_updates"] > 0

    def durs(log):
        out = {}
        for line in log:
            m = re.match(r"t=.* COMPLETE cid=(\d+) v=\d+ dur=(.*)$", line)
            if m:
                out.setdefault(int(m.group(1)), []).append(m.group(2))
        return out
    a, c = durs(clean["event_log"]), durs(faulty["event_log"])
    for cid, seq in c.items():
        ref = a.get(cid, [])
        k = min(len(ref), len(seq))
        assert seq[:k] == ref[:k]


# ---------------------------------------------------------------------------
# robust aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ROBUST_METHODS)
def test_fleet_robust_aggregators_train(bundle, method):
    b = bundle
    cfg = FleetConfig(epochs=1, batch_size=8, seed=0, aggregator=method)
    out = run_fleet(b.model, b.train, b.specs, cfg, rounds=2,
                    test_data=b.test, faults="byzantine_signflip")
    assert out["aggregator"] == method
    assert np.isfinite(out["history"][-1].test_loss)
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(out["params"]))


def test_robust_beats_mean_under_byzantine(bundle):
    # sign-flip only *slows* the mean early on; the separation appears
    # once the honest clients approach their optimum and the Byzantine
    # bias becomes the binding constraint — hence the longer horizon
    b = bundle
    accs = {}
    for agg in ("weighted_mean", "trimmed_mean", "norm_clip"):
        cfg = FleetConfig(epochs=1, batch_size=8, seed=0, aggregator=agg)
        out = run_fleet(b.model, b.train, b.specs, cfg, rounds=12,
                        test_data=b.test, faults="byzantine_signflip")
        accs[agg] = out["history"][-1].test_acc
    assert max(accs["trimmed_mean"], accs["norm_clip"]) > accs["weighted_mean"]


@pytest.mark.parametrize("method", ROBUST_METHODS)
def test_async_fleet_robust_merges_train(bundle, method):
    b = bundle
    cfg = AsyncFleetConfig(max_updates=2, buffer_k=6, concurrency=10,
                           epochs=1, batch_size=8, seed=0)
    out = run_async_fleet(b.model, b.train, b.specs, cfg, test_data=b.test,
                          aggregator=method, faults="byzantine_signflip")
    assert out["aggregator"] == method
    assert out["telemetry"]["n_corrupted_updates"] > 0
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(out["params"]))


def test_aggregator_flush_empty_buffer_is_noop():
    params = {"w": np.ones(3, np.float32)}
    for name, factory in AGGREGATORS.items():
        agg = factory()
        agg.reset()
        assert agg.flush(params) is None, name


def test_weighted_mean_zero_weights_falls_back():
    params = {"w": np.ones(3, np.float32)}
    trees = [{"w": np.full(3, 5.0, np.float32)}]
    out = weighted_mean_params(trees, [0], weight_by_samples=True,
                               fallback=params)
    assert out is params
    with pytest.raises(ValueError):
        weighted_mean_params(trees, [0], weight_by_samples=True)
    with pytest.raises(ValueError):
        weighted_mean_params([], [], weight_by_samples=False)


# ---------------------------------------------------------------------------
# checkpoint / resume byte-identity
# ---------------------------------------------------------------------------

def test_fleet_resume_byte_identity(bundle, tmp_path):
    b = bundle
    cfg = FleetConfig(epochs=1, batch_size=8, seed=0)
    kw = dict(test_data=b.test, faults="dropout")
    full = run_fleet(b.model, b.train, b.specs, cfg, rounds=5,
                     scheduler=AdaptiveParticipation(b.specs), **kw)
    d = str(tmp_path / "fleet")
    run_fleet(b.model, b.train, b.specs, cfg, rounds=3,
              scheduler=AdaptiveParticipation(b.specs),
              checkpoint_dir=d, checkpoint_every=1, **kw)
    res = run_fleet(b.model, b.train, b.specs, cfg, rounds=5,
                    scheduler=AdaptiveParticipation(b.specs),
                    checkpoint_dir=d, resume=True, **kw)
    assert _same_tree(full["params"], res["params"])
    assert [h.__dict__ for h in full["history"]] == \
        [h.__dict__ for h in res["history"]]


def test_async_fleet_resume_byte_identity(bundle, tmp_path):
    b = bundle
    cfg = AsyncFleetConfig(max_updates=5, buffer_k=5, concurrency=10,
                           epochs=1, batch_size=8, seed=0, eval_every=1)
    kw = dict(test_data=b.test, faults="dropout")
    full = run_async_fleet(b.model, b.train, b.specs, cfg,
                           scheduler=AdaptiveParticipation(b.specs), **kw)
    d = str(tmp_path / "async_fleet")
    cfg_half = dataclasses.replace(cfg, max_updates=2)
    run_async_fleet(b.model, b.train, b.specs, cfg_half,
                    scheduler=AdaptiveParticipation(b.specs),
                    checkpoint_dir=d, checkpoint_every=1, **kw)
    res = run_async_fleet(b.model, b.train, b.specs, cfg,
                          scheduler=AdaptiveParticipation(b.specs),
                          checkpoint_dir=d, resume=True, **kw)
    assert _same_tree(full["params"], res["params"])
    assert full["event_log"] == res["event_log"]
    assert [h.__dict__ for h in full["history"]] == \
        [h.__dict__ for h in res["history"]]


# ---------------------------------------------------------------------------
# scenario threading
# ---------------------------------------------------------------------------

def test_scenario_faults_axis_all_runtimes(bundle):
    b = bundle
    kw = dict(model=b.model, clients_data=b.train, test_data=b.test,
              rounds=2, clients_per_round=5, epochs=1, batch_size=8)
    for runtime in ("sync", "async", "fleet", "async_fleet"):
        out = run_scenario("uniform", runtime, faults="byzantine_signflip",
                           aggregator="trimmed_mean", **kw)
        assert out["faults"] == "byzantine_signflip"
        assert all(np.all(np.isfinite(np.asarray(l)))
                   for l in jax.tree.leaves(out["params"]))


def test_scenario_label_skew_preserves_specs(bundle):
    b = bundle
    kw = dict(model=b.model, clients_data=b.train, test_data=b.test,
              rounds=1, clients_per_round=5, epochs=1, batch_size=8)
    a = run_scenario("uniform", "sync", **kw)
    c = run_scenario("uniform", "sync", faults="label_skew", **kw)
    # sizes (and hence specs/deadlines) are invariant under label skew
    assert a["deadline"] == c["deadline"]
