"""Hypothesis property tests for cohort grouping and the pow-4 budget
quantizer (auto-skip when hypothesis is absent, like the MoE properties).

Invariants under arbitrary size/budget draws:
  * every cohort client appears in exactly one group (exact partition);
  * a group's padded size is the next power-of-two number of batches;
  * the quantized group budget k is a power of four that never exceeds
    any member's requested budget nor its count of valid (real) rows;
  * per-client epoch permutations are true permutations of the padded
    range, and the valid mask counts exactly m real rows;
  * an empty cohort yields no groups (the driver's no-op round contract).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.fed.fleet.batched import (_floor_pow4, _next_pow2,  # noqa: E402
                                     FleetConfig, make_cohort_groups)


def _is_pow4(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0 and (n.bit_length() - 1) % 2 == 0


@given(st.integers(min_value=1, max_value=10**9))
def test_floor_pow4_quantizer_properties(n):
    q = _floor_pow4(n)
    assert _is_pow4(q)
    assert q <= n < 4 * q          # tightest pow-4 below: floor semantics


@given(st.integers(min_value=1, max_value=10**6))
def test_next_pow2_properties(n):
    p = _next_pow2(n)
    assert p >= n and (p & (p - 1)) == 0
    assert p < 2 * n or n == 1     # tightest pow-2 at or above


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_cohort_group_invariants(data):
    n = data.draw(st.integers(min_value=0, max_value=10), label="n_clients")
    batch_size = data.draw(st.sampled_from([2, 4, 8]), label="batch_size")
    epochs = data.draw(st.integers(min_value=1, max_value=3), label="epochs")
    sizes = data.draw(st.lists(st.integers(min_value=1, max_value=70),
                               min_size=n, max_size=n), label="sizes")
    budgets = {i: data.draw(st.integers(min_value=1, max_value=100),
                            label=f"budget[{i}]") for i in range(n)}
    clients = [{"x": np.zeros((m, 3), np.float32),
                "y": np.zeros(m, np.int32)} for m in sizes]
    cfg = FleetConfig(epochs=epochs, batch_size=batch_size, seed=0)
    groups = make_cohort_groups(clients, list(range(n)), budgets, cfg,
                                round_seed=1)

    if n == 0:                     # empty-cohort invariant
        assert groups == []
        return

    # exact partition: every client in exactly one group
    seen = np.concatenate([g.cids for g in groups])
    assert sorted(seen.tolist()) == list(range(n))

    for g in groups:
        c, m_pad = g.valid.shape
        assert len(g.cids) == c == len(g.m)
        # padded size is the next pow2 number of batches
        for i, cid in enumerate(g.cids):
            m = sizes[cid]
            assert m_pad == _next_pow2(-(-m // batch_size)) * batch_size
            assert g.m[i] == m == g.valid[i].sum()
            assert g.valid[i, :m].all() and not g.valid[i, m:].any()
        # quantized budget: pow4, never above any member's request or
        # its valid rows (k == 0 means full-set training)
        if g.k > 0:
            assert _is_pow4(g.k)
            for i, cid in enumerate(g.cids):
                assert g.k <= budgets[cid]
                assert g.k <= g.m[i]           # never exceeds valid rows
        else:
            assert all(budgets[cid] >= sizes[cid] for cid in g.cids)
        # per-epoch permutations of the padded range
        assert g.perms.shape == (c, epochs, m_pad)
        for i in range(c):
            for e in range(epochs):
                assert np.array_equal(np.sort(g.perms[i, e]),
                                      np.arange(m_pad))
