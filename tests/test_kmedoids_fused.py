"""Fused coreset-selection fast-path tests.

Covers the PR 4 selection pipeline end to end: the Pallas BUILD/Δ-sweep
kernels against their jnp oracles, medoid-index parity against the
``kmedoids_numpy`` oracle over 100+ randomized masked/padded instances
(k = 1, duplicate points, all-valid, mostly-padded lanes), the
legacy-sweep A/B baseline, diagonal-zeroing ownership by the pairwise
wrappers, and the single-dispatch contract of the fused per-group round
program.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fixed_size_clients
from repro.core.kmedoids import (kmedoids_batched, kmedoids_numpy,
                                 pairwise_sq_dists)
from repro.fed.fleet.batched import (FleetConfig, FleetEngine,
                                     make_cohort_groups, run_fleet_round)
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# kernels vs jnp oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,m", [(3, 64), (2, 21), (1, 128), (4, 8)])
def test_build_cost_kernel_matches_ref(c, m):
    rng = np.random.default_rng(c * 100 + m)
    D = jnp.asarray(np.abs(rng.normal(size=(c, m, m))).astype(np.float32))
    d_near = jnp.asarray(np.abs(rng.normal(size=(c, m))).astype(np.float32))
    vf = jnp.asarray((rng.random((c, m)) < 0.8).astype(np.float32))
    got = ops.kmedoids_build_cost(D, d_near, vf, use_kernel=True)
    want = ref.kmedoids_build_cost_ref(D, d_near, vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("c,m,k", [(3, 64, 5), (2, 21, 1), (1, 128, 16),
                                   (4, 32, 3)])
def test_delta_sweep_kernel_matches_ref(c, m, k):
    rng = np.random.default_rng(c * 1000 + m + k)
    D = jnp.asarray(np.abs(rng.normal(size=(c, m, m))).astype(np.float32))
    d1 = np.abs(rng.normal(size=(c, m))).astype(np.float32)
    d2 = d1 + np.abs(rng.normal(size=(c, m))).astype(np.float32)  # d1 <= d2
    n_idx = rng.integers(0, k, size=(c, m))
    onehot = np.eye(k, dtype=np.float32)[n_idx]
    vf = (rng.random((c, m)) < 0.8).astype(np.float32)
    args = (D, jnp.asarray(d1), jnp.asarray(d2), jnp.asarray(vf),
            jnp.asarray(onehot))
    A, B = ops.kmedoids_delta_sweep(*args, use_kernel=True)
    A_ref, B_ref = ref.kmedoids_delta_sweep_ref(*args)
    np.testing.assert_allclose(np.asarray(A), np.asarray(A_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B_ref),
                               rtol=1e-5, atol=1e-5)
    assert B.shape == (c, m, k)   # padded lanes sliced off


# ---------------------------------------------------------------------------
# medoid-index parity vs the numpy oracle (the acceptance contract)
# ---------------------------------------------------------------------------

def _oracle_instance(rng, kind, m_pad, k):
    """One masked/padded instance: (D_padded, valid, D_true float32)."""
    if kind == "all_valid":
        m = m_pad
    elif kind == "mostly_padded":
        m = int(rng.integers(max(k, 2), max(k + 1, m_pad // 5)))
    else:
        m = int(rng.integers(max(k, 4), m_pad + 1))
    x = rng.normal(size=(m, 5)).astype(np.float32)
    if kind == "clusters" and m >= 6:
        x[: m // 3] += 4.0
        x[m // 3: 2 * m // 3] -= 4.0
    if kind == "duplicates" and m >= 2 * k:
        x[1::2] = x[::2][: len(x[1::2])]     # exact duplicate points
    D = np.sqrt(np.maximum(
        np.asarray(pairwise_sq_dists(jnp.asarray(x))), 0.0)).astype(
            np.float32)
    Dp = (np.abs(rng.normal(size=(m_pad, m_pad))) * 37).astype(np.float32)
    Dp[:m, :m] = D
    valid = np.arange(m_pad) < m
    return Dp, valid, D


KINDS = ("plain", "clusters", "duplicates", "mostly_padded", "all_valid")


def _canon_medoids(meds, D):
    """Map each medoid to the smallest index at (near-)zero distance from
    it — its duplicate class — sorted.  Duplicate points are
    interchangeable optima; float32 cancellation in ‖a‖²+‖b‖²−2ab can
    leave ~1e-4 between bitwise-equal points after the sqrt, and
    f32-vs-f64 near-ties mid-run may settle on either copy."""
    return sorted(int(np.flatnonzero(D[:, int(j)] < 1e-3).min())
                  for j in np.asarray(meds))


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_medoids_bit_identical_to_numpy_oracle(use_kernel, k):
    """Medoid indices from the fused batched solver (kernel and jnp paths)
    equal the float64 host oracle's on randomized masked instances —
    18 lanes per (k, kernel) combination, 108 instances total across the
    parametrization (the ≥50-instance acceptance bar), solved as three
    18-lane batched calls to also exercise lane independence.  Lanes with
    exact duplicate points compare up to the duplicate class (tied
    optima); every other lane must match index-for-index, bit-identical."""
    m_pad = 32
    rng = np.random.default_rng(1000 + k)
    Ds, valids, trues = [], [], []
    for i in range(18):
        kind = KINDS[i % len(KINDS)]
        Dp, valid, D = _oracle_instance(rng, kind, m_pad, k)
        Ds.append(Dp)
        valids.append(valid)
        trues.append(D)
    res = kmedoids_batched(jnp.asarray(np.stack(Ds)),
                           jnp.asarray(np.stack(valids)), k,
                           max_sweeps=100, use_kernel=use_kernel)
    for c, D in enumerate(trues):
        kind = KINDS[c % len(KINDS)]
        want = kmedoids_numpy(D, k, max_sweeps=100)
        got_meds = np.asarray(res.medoids[c])
        if kind == "duplicates":
            assert _canon_medoids(got_meds, D) == \
                _canon_medoids(want.medoids, D), \
                f"lane {c} kind={kind} k={k} use_kernel={use_kernel}"
            np.testing.assert_allclose(float(res.objective[c]),
                                       float(want.objective), rtol=1e-5)
        else:
            np.testing.assert_array_equal(
                got_meds, np.asarray(want.medoids),
                err_msg=f"lane {c} kind={kind} k={k} "
                        f"use_kernel={use_kernel}")
            np.testing.assert_array_equal(np.asarray(res.weights[c]),
                                          np.asarray(want.weights))
        # weights always partition the m real samples; padding excluded
        m = int(valids[c].sum())
        assert int(np.asarray(res.weights[c]).sum()) == m
        assert (np.asarray(res.assignment[c])[m:] == -1).all()


def test_legacy_sweep_is_equivalent_baseline():
    """The pre-fusion minimum/one_hot/einsum chain (the selection
    benchmark's A/B baseline) picks identical medoids to the fused
    Δ-sweep formulation — the clip form is a bitwise case-collapse."""
    rng = np.random.default_rng(7)
    Ds, valids = [], []
    for _ in range(6):
        Dp, valid, _ = _oracle_instance(rng, "plain", 32, 4)
        Ds.append(Dp)
        valids.append(valid)
    D = jnp.asarray(np.stack(Ds))
    v = jnp.asarray(np.stack(valids))
    new = kmedoids_batched(D, v, 4, max_sweeps=100)
    old = kmedoids_batched(D, v, 4, max_sweeps=100, legacy_sweep=True)
    np.testing.assert_array_equal(np.asarray(new.medoids),
                                  np.asarray(old.medoids))
    np.testing.assert_allclose(np.asarray(new.objective),
                               np.asarray(old.objective), rtol=1e-6)


# ---------------------------------------------------------------------------
# diagonal zeroing lives in the pairwise wrappers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_pairwise_wrappers_own_self_diag(use_kernel):
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 40, 24))
    out = np.asarray(ops.pairwise_l2_batched(x, squared=True,
                                             use_kernel=use_kernel,
                                             zero_diag=True))
    for c in range(3):
        assert (np.diag(out[c]) == 0.0).all()
    d = np.asarray(pairwise_sq_dists(x[0], use_kernel=use_kernel))
    assert (np.diag(d) == 0.0).all()


# ---------------------------------------------------------------------------
# fused per-group round program: single dispatch
# ---------------------------------------------------------------------------

def _tiny_fleet(n_clients=6, m=40, seed=0):
    # deduped into conftest: same-size mlp clients so one budget maps to
    # exactly one cohort group
    return fixed_size_clients("mlp", n_clients=n_clients, m=m, seed=seed)


def test_fused_group_program_is_single_dispatch():
    """A straggler group's full round (features → distances → k-medoids →
    SGD → gather → coreset epochs) must execute as exactly one jitted
    program invocation — no other engine program may be touched."""
    model, data = _tiny_fleet()
    cfg = FleetConfig(epochs=2, batch_size=8, seed=0)
    engine = FleetEngine(model, cfg)
    params = model.init(jax.random.PRNGKey(0))
    cids = list(range(len(data)))
    budgets = {cid: 9 for cid in cids}           # -> coreset path, k = 4
    groups = make_cohort_groups(data, cids, budgets, cfg, 0)
    assert len(groups) == 1 and groups[0].k == 4
    g = groups[0]

    key = (g.k, jax.tree.structure(g.data))
    program = engine._group_program(g.k, key[1])
    calls = []

    def counting(*args):
        calls.append(1)
        return program(*args)

    engine._group_programs[key] = counting
    # the fused path must not fall back to the pre-fusion stage programs
    engine._feats = engine._feats1 = None
    engine._sgd_step1 = engine._core_step1 = None

    before = engine.dispatch_count
    p, losses, meds = engine.run_group(params, g, batched=True)
    assert len(calls) == 1
    assert engine.dispatch_count - before == 1
    assert meds is not None and meds.shape == (g.n_clients, g.k)
    assert np.isfinite(losses).all()


def test_selection_fused_matches_prefusion_chain_dispatch_counts():
    """select_group_coresets: the 1-dispatch fused program (distance-free)
    and the 3-dispatch pre-fusion baseline chain (materializing) select
    equivalent medoids — equal up to tied-optima classes, scored on one
    shared float64 distance matrix — and the dispatch counts don't
    regress.  (Exact index equality is no longer guaranteed: the two
    paths accumulate distances in different orders, and equal-cost swap
    ties may settle on either optimum.)"""
    model, data = _tiny_fleet(seed=3)
    cfg = FleetConfig(epochs=2, batch_size=8, seed=0)
    engine = FleetEngine(model, cfg)
    params = model.init(jax.random.PRNGKey(1))
    cids = list(range(len(data)))
    groups = make_cohort_groups(data, cids, {c: 20 for c in cids}, cfg, 0)
    g = groups[0]
    assert g.k == 16
    fused, n_fused = engine.select_group_coresets(params, g, fused=True)
    chain, n_chain = engine.select_group_coresets(params, g, fused=False)
    assert (n_fused, n_chain) == (1, 3)
    np.testing.assert_allclose(np.asarray(fused.objective),
                               np.asarray(chain.objective), rtol=1e-6)
    feats = np.asarray(engine._feats(params,
                                     jax.tree.map(jnp.asarray, g.data)),
                       np.float64)
    for c in range(g.n_clients):
        m = int(g.m[c])
        x = feats[c, :m]
        sq = (x * x).sum(-1)
        D64 = np.sqrt(np.maximum(
            sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0))
        np.fill_diagonal(D64, 0.0)

        def obj(meds):
            assert (np.asarray(meds) < m).all()   # never a padded lane
            return D64[:, np.asarray(meds)].min(axis=1).sum()

        fo, co = obj(fused.indices[c]), obj(chain.indices[c])
        np.testing.assert_allclose(fo, co, rtol=1e-5,
                                   err_msg=f"lane {c}: fused and chain "
                                           f"medoids are not cost-tied")
        # both weight vectors partition the same m real samples
        assert int(np.asarray(fused.weights[c]).sum()) == m
        assert int(np.asarray(chain.weights[c]).sum()) == m


def test_round_dispatch_count_is_one_per_group():
    """run_fleet_round on the batched engine issues exactly one top-level
    dispatch per cohort group (the pre-fusion engine issued up to six)."""
    model, data = _tiny_fleet(n_clients=8, seed=5)
    cfg = FleetConfig(epochs=2, batch_size=8, seed=0)
    engine = FleetEngine(model, cfg)
    params = model.init(jax.random.PRNGKey(0))
    cids = list(range(len(data)))
    # half full-set, half coreset -> two groups
    budgets = {c: (40 if c < 4 else 9) for c in cids}
    groups = make_cohort_groups(data, cids, budgets, cfg, 0)
    before = engine.dispatch_count
    run_fleet_round(engine, params, data, cids, budgets, round_seed=0,
                    groups=groups)
    assert engine.dispatch_count - before == len(groups) == 2


def test_use_kernel_tristate_resolution():
    """FleetConfig.use_kernel = None resolves by backend (off on CPU) and
    both forced settings agree with the auto result numerically."""
    assert ops.resolve_use_kernel(None) == (jax.default_backend() == "tpu")
    assert ops.resolve_use_kernel(True) is True
    assert ops.resolve_use_kernel(False) is False
    model, data = _tiny_fleet(seed=11)
    params = model.init(jax.random.PRNGKey(2))
    cids = list(range(len(data)))
    budgets = {c: 9 for c in cids}
    meds = {}
    for uk in (None, True, False):
        cfg = FleetConfig(epochs=2, batch_size=8, seed=0, use_kernel=uk)
        engine = FleetEngine(model, cfg)
        groups = make_cohort_groups(data, cids, budgets, cfg, 0)
        cs, _ = engine.select_group_coresets(params, groups[0], fused=True)
        meds[uk] = np.asarray(cs.indices)
    np.testing.assert_array_equal(meds[None], meds[True])
    np.testing.assert_array_equal(meds[None], meds[False])


def test_fleet_config_replace_keeps_frozen_contract():
    """The benchmark builds kernel-A/B engines via dataclasses.replace —
    keep FleetConfig replace-compatible."""
    cfg = FleetConfig(epochs=3, use_kernel=None)
    on = dataclasses.replace(cfg, use_kernel=True)
    assert on.use_kernel is True and on.epochs == 3
