"""Property tests on the strategy timing/work models (hypothesis): the
deadline guarantees of Alg. 1 over random client populations."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coreset import coreset_budget, needs_coreset
from repro.fed.simulator import ClientSpec, straggler_deadline
from repro.fed.strategies import FORWARD_FRAC


def _fedcore_work(m, c, tau, E):
    """Mirror of FedCore.local_update's work model (strategies.py)."""
    if not needs_coreset(m, c, tau, E):
        return E * m
    if c * tau > m and E > 1:
        b = coreset_budget(m, c, tau, E)
        w = m + (E - 1) * b
        if w <= c * tau:
            return w
    avail = c * tau - FORWARD_FRAC * m
    b = max(1, min(int(avail // E), m))
    ep = max(1, min(E, int(avail // b)))
    return FORWARD_FRAC * m + ep * b


@settings(max_examples=200, deadline=None)
@given(m=st.integers(8, 5000), c=st.floats(0.05, 3.0),
       tau_mult=st.floats(0.1, 3.0), E=st.integers(2, 20))
def test_fedcore_meets_deadline_whenever_feasible(m, c, tau_mult, E):
    """If the client can afford a forward pass + 1 sample*epoch, FedCore's
    schedule fits within tau; otherwise it degrades to the minimum
    feasible work (footnote-2 regime)."""
    tau = tau_mult * E * m  # deadline relative to unit-capability full work
    work = _fedcore_work(m, c, tau, E)
    min_feasible = FORWARD_FRAC * m + 1  # feature pass + one sample
    if c * tau >= min_feasible + E:  # comfortably feasible
        assert work <= c * tau + 1e-6, (m, c, tau, E, work)
    # work is never more than full-set training
    assert work <= E * m + 1e-9


@settings(max_examples=100, deadline=None)
@given(m=st.integers(8, 5000), c=st.floats(0.3, 3.0), E=st.integers(2, 20))
def test_fast_clients_do_full_work(m, c, E):
    tau = E * m / c * 1.01  # just enough for full-set
    assert not needs_coreset(m, c, tau, E)
    assert _fedcore_work(m, c, tau, E) == E * m


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), pct=st.sampled_from([10.0, 30.0]))
def test_deadline_percentile(seed, pct):
    rng = np.random.default_rng(seed)
    specs = [ClientSpec(i, int(m), float(max(c, 0.05)))
             for i, (m, c) in enumerate(zip(
                 rng.integers(10, 1000, 200),
                 rng.normal(1.0, 0.5, 200)))]
    tau = straggler_deadline(specs, 10, pct)
    frac_over = np.mean([s.full_round_time(10) > tau for s in specs])
    assert abs(frac_over - pct / 100) < 0.06
