"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# pairwise_l2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [(128, 128, 128), (256, 128, 512),
                                   (131, 59, 70), (64, 64, 8), (300, 300, 260)])
@pytest.mark.parametrize("squared", [True, False])
def test_pairwise_l2_shapes(m, n, d, squared):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (n, d), jnp.float32)
    out = ops.pairwise_l2(x, y, squared=squared)
    expected = ref.pairwise_l2_ref(x, y, squared=squared)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_self_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 256)).astype(dtype)
    out = ops.pairwise_l2(x)
    expected = ref.pairwise_l2_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=3e-2, atol=3e-2)


def test_pairwise_l2_self_diag_zero():
    x = jax.random.normal(jax.random.PRNGKey(3), (96, 40))
    out = np.asarray(ops.pairwise_l2(x, squared=True))
    np.testing.assert_allclose(np.diag(out), 0.0, atol=1e-4)
    np.testing.assert_allclose(out, out.T, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hk,s,hd", [
    (2, 4, 2, 128, 64), (1, 4, 4, 256, 32), (2, 8, 1, 128, 64),
    (1, 2, 2, 64, 128),
])
def test_flash_attention_gqa(b, hq, hk, s, hd):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, hk, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, hk, s, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    expected = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 48, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    expected = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    expected = ref.flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(dtype))


def test_flash_matches_model_attention_math():
    """Kernel agrees with the model-layer chunked attention implementation."""
    from repro.configs.base import ModelConfig
    from repro.models.attention import _attend_chunked
    b, hq, hk, s, hd = 1, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hk, hd), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    scale = 1.0 / np.sqrt(hd)
    chunked = _attend_chunked(q, k, v, pos, pos, True, None, scale)
    kernel = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(kernel.transpose(0, 2, 1, 3)),
                               np.asarray(chunked), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 37, 96), (256, 512), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(4), shape).astype(dtype)
    scale = jax.random.normal(jax.random.PRNGKey(5), (shape[-1],))
    out = ops.rmsnorm(x, scale)
    expected = ref.rmsnorm_ref(x, scale)
    assert out.shape == x.shape and out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               **_tol(dtype))
