"""Sharding-rule tests on a small host mesh (4 fake devices via a 2x2 mesh
would need multi-device; here we validate spec construction logic, which is
device-count independent, against a mocked mesh shape)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.distributed.sharding import (_fit, batch_specs,
                                        decode_state_specs, param_specs)
from repro.models.model import Model


class FakeMesh:
    """Duck-typed mesh exposing .shape and .axis_names only."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _norm(sp):
    t = tuple(sp)
    while t and t[-1] is None:
        t = t[:-1]
    return t


def test_fit_drops_nondivisible():
    assert _norm(_fit(P("model"), (10,), MESH)) == ()
    assert _norm(_fit(P("model"), (32,), MESH)) == ("model",)
    assert _norm(_fit(P(("pod", "data")), (64, 8), MESH3)) == (
        ("pod", "data"),)
    assert _norm(_fit(P(("pod", "data")), (30, 8), MESH3)) == ()


def _specs_for(arch):
    cfg = get_config(arch)
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return cfg, shapes, param_specs(cfg, shapes, MESH)


def test_dense_param_specs():
    cfg, shapes, specs = _specs_for("yi-9b")
    # stacked attn wq: (L, d, hq*hd) -> shard output dim over model
    assert tuple(specs["layers"]["attn"]["wq"]) == (None, None, "model")
    assert tuple(specs["layers"]["attn"]["wo"]) == (None, "model", None)
    assert tuple(specs["layers"]["mlp"]["w_down"]) == (None, "model", None)
    # embeddings: vocab over model (64000 % 16 == 0)
    assert tuple(specs["embed"]) == ("model", None)
    # norms replicated
    assert tuple(specs["ln_f"]["scale"]) == ()


def test_moe_expert_parallel_specs():
    cfg, shapes, specs = _specs_for("llama4-scout-17b-a16e")
    # experts over model: (L, E, d, f)
    assert tuple(specs["layers"]["moe"]["w_gate"]) == (None, "model", None,
                                                       None)
    assert tuple(specs["layers"]["moe"]["w_down"]) == (None, "model", None,
                                                       None)


def test_mqa_kv_cache_not_sharded_on_heads():
    cfg = get_config("granite-20b")  # kv_heads = 1
    model = Model(cfg)
    state = jax.eval_shape(
        lambda: model.init_decode_state(None, 128, 1024))
    specs = decode_state_specs(cfg, state, MESH)
    kv_spec = tuple(specs["kv"]["k"])
    # heads dim (idx 3) must NOT be sharded (1 % 16 != 0)
    assert len(kv_spec) < 4 or kv_spec[3] is None


def test_context_parallel_shards_cache_seq():
    cfg = get_config("mistral-large-123b")
    model = Model(cfg)
    state = jax.eval_shape(
        lambda: model.init_decode_state(None, 128, 32768))
    specs = decode_state_specs(cfg, state, MESH, context_parallel=True)
    kv_spec = tuple(specs["kv"]["k"])
    assert kv_spec[2] == "model"  # cache seq dim sharded


def test_batch_specs_divisibility():
    cfg = get_config("yi-9b")
    model = Model(cfg)
    # train_4k batch 256 % 16 == 0 -> sharded
    sp = batch_specs(model.input_specs(SHAPES["train_4k"]), MESH)
    assert tuple(sp["tokens"])[0] in ("data", ("data",))
    # long_500k batch 1 -> replicated
    sp = batch_specs(model.input_specs(SHAPES["long_500k"]), MESH)
    assert _norm(sp["token"]) == ()


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-125m",
                                  "whisper-tiny", "pixtral-12b"])
def test_specs_build_for_every_family(arch):
    cfg, shapes, specs = _specs_for(arch)
    # every leaf got a spec and no spec exceeds leaf rank
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_l)
    for sp, leaf in zip(flat_s, flat_l):
        assert len(tuple(sp)) <= len(leaf.shape)
