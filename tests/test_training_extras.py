"""Gradient accumulation + pallas attention-impl parity tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.attention import init_attention, multihead_attention
from repro.models.model import Model
from repro.models.training import make_train_step
from repro.optim.optimizers import sgd


def test_grad_accumulation_matches_full_batch():
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 64),
    }
    opt = sgd(0.1)
    full = make_train_step(model.loss, opt, donate=False)
    accum = make_train_step(model.loss, opt, accum_steps=4, donate=False)
    p1, _, m1 = full(params, opt.init(params), batch)
    p2, _, m2 = accum(params, opt.init(params), batch)
    # same per-example weighting (uniform) => identical gradients
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-6)
    np.testing.assert_allclose(float(m1["total_loss"]),
                               float(m2["total_loss"]), rtol=1e-5)


@pytest.mark.parametrize("window", [None, 32])
def test_pallas_impl_matches_naive_in_model_layer(window):
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=100)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    a = multihead_attention(p, cfg, x, causal=True, window=window,
                            impl="naive")
    b = multihead_attention(p, cfg, x, causal=True, window=window,
                            impl="pallas")  # interpret mode on CPU
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_pallas_impl_cross_attention_falls_back():
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 64))
    kv = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 64))
    a = multihead_attention(p, cfg, x, causal=False, impl="pallas",
                            kv_x=kv, use_rope=False)
    b = multihead_attention(p, cfg, x, causal=False, impl="naive",
                            kv_x=kv, use_rope=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
