"""Roofline analytic-model validation.

The roofline terms come from the analytic cost model (XLA's cost_analysis
counts lax.scan bodies once — see benchmarks/roofline.py).  Here we
cross-validate the analytic FLOPs against cost_analysis on configs where
the undercount cannot occur (single layer => scan trip count 1, naive
attention, no inner scans), and sanity-check the collective parser.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.roofline import (collective_bytes_per_chip, forward_flops,
                                 hbm_bytes, model_flops, roofline)
from repro.configs import get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models.model import Model


def _measured_flops(cfg, batch, seq):
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }

    def fwd(p, b):
        logits, _, _ = model.forward(p, b, impl="naive")
        return logits

    compiled = jax.jit(fwd).lower(params, batch_abs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else None
    if not ca:
        pytest.skip("cost_analysis unavailable on this jax version")
    return ca["flops"]


@pytest.mark.parametrize("d_ff,vocab", [(512, 512), (1024, 2048)])
def test_analytic_flops_match_xla_single_layer(d_ff, vocab):
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=1, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=d_ff, vocab_size=vocab)
    batch, seq = 2, 128
    analytic = forward_flops(cfg, batch, seq)
    measured = _measured_flops(cfg, batch, seq)
    # naive attention counts full SxS (analytic uses S/2 causal average);
    # allow the softmax/norm overhead band
    assert 0.5 < measured / analytic < 2.0, (analytic, measured)


def test_train_flops_3x_forward():
    cfg = get_config("yi-9b")
    shape = SHAPES["train_4k"]
    r = roofline("yi-9b", "train_4k", {"data": 16, "model": 16})
    fwd = forward_flops(cfg, shape.global_batch, shape.seq_len)
    assert abs(r["flops"] / fwd - 3.0) < 1e-6


def test_model_flops_6nd():
    cfg = get_config("yi-9b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    expected = 6.0 * cfg.active_param_count() * shape.global_batch * \
        shape.seq_len
    assert mf == expected


def test_useful_ratio_below_one_for_attention_archs():
    """Analytic HLO flops >= 6ND because attention quadratic terms are
    extra — the ratio must be in (0, 1.05] for the dense archs."""
    for arch in ("yi-9b", "mistral-large-123b", "command-r-35b"):
        r = roofline(arch, "train_4k", {"data": 16, "model": 16})
        assert 0.5 < r["useful_flops_ratio"] <= 1.05, (arch, r)


def test_decode_memory_bound():
    """Decode at batch 128 with a 32k cache must be memory-dominated on
    v5e for every dense arch (weights+cache >> flops)."""
    for arch in ("yi-9b", "granite-20b", "command-r-35b"):
        r = roofline(arch, "decode_32k", {"data": 16, "model": 16})
        assert r["dominant"] == "memory", (arch, r["dominant"])


def test_window_cuts_attention_flops():
    cfg = get_config("yi-9b")
    full = forward_flops(cfg, 1, 32768)
    cfg_w = cfg.with_(attention_window=4096)
    windowed = forward_flops(cfg_w, 1, 32768)
    assert windowed < full


def test_remat_cuts_memory_term():
    shape = SHAPES["train_4k"]
    cfg = get_config("mistral-large-123b")
    base = hbm_bytes(cfg, shape, 256, remat=False)
    rem = hbm_bytes(cfg, shape, 256, remat=True)
    assert rem < base


def test_collective_model_scales_with_tp():
    cfg = get_config("yi-9b")
    shape = SHAPES["train_4k"]
    c16 = collective_bytes_per_chip(cfg, shape,
                                    {"data": 16, "model": 16})["total"]
    c8 = collective_bytes_per_chip(cfg, shape,
                                   {"data": 32, "model": 8})["total"]
    assert c8 < c16  # less TP + more DP => fewer activation all-reduce bytes


def test_multi_pod_adds_dcn_term():
    cfg = get_config("yi-9b")
    shape = SHAPES["train_4k"]
    c = collective_bytes_per_chip(cfg, shape,
                                  {"pod": 2, "data": 16, "model": 16})
    assert c["dcn"] > 0


def test_collective_parser():
    from repro.launch.dryrun import collective_stats
    hlo = """
  %all-reduce = f32[64,128]{1,0} all-reduce(%dot.1), channel_id=1
  %ag = bf16[32,256]{1,0} all-gather(%p0), dimensions={0}
  %rs.1 = f32[16]{0} reduce-scatter(%x), dimensions={0}
  %other = f32[8,8]{1,0} add(%a, %b)
"""
    stats = collective_stats(hlo)
    assert stats["counts"]["all-reduce"] == 1
    assert stats["bytes_by_op"]["all-reduce"] == 64 * 128 * 4
    assert stats["bytes_by_op"]["all-gather"] == 32 * 256 * 2
    assert stats["bytes_by_op"]["reduce-scatter"] == 16 * 4
    assert stats["total_bytes"] == 64 * 128 * 4 + 32 * 256 * 2 + 16 * 4
