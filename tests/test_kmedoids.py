"""k-medoids solver tests: oracle agreement, invariants, property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.kmedoids import (kmedoids_jax, kmedoids_numpy,
                                 pairwise_sq_dists)


def _random_instance(seed, m=80, d=6, k=8, clusters=True):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    if clusters:
        x[: m // 3] += 4.0
        x[m // 3: 2 * m // 3] -= 4.0
    D = np.sqrt(np.maximum(np.asarray(
        pairwise_sq_dists(jnp.asarray(x))), 0.0))
    return x, D


@pytest.mark.parametrize("seed", range(4))
def test_jax_matches_numpy_objective(seed):
    _, D = _random_instance(seed)
    rn = kmedoids_numpy(D, 8)
    rj = kmedoids_jax(jnp.asarray(D), 8)
    assert float(rj.objective) <= float(rn.objective) * 1.001 + 1e-5


def test_invariants():
    _, D = _random_instance(0)
    res = kmedoids_jax(jnp.asarray(D), 10)
    m = D.shape[0]
    # medoids are distinct dataset points
    meds = np.asarray(res.medoids)
    assert len(set(meds.tolist())) == 10
    assert meds.min() >= 0 and meds.max() < m
    # weights sum to m (paper: Σδ = mⁱ)
    assert int(np.sum(np.asarray(res.weights))) == m
    # assignment is the argmin over medoids
    dm = D[:, meds]
    np.testing.assert_array_equal(np.asarray(res.assignment), dm.argmin(1))
    # objective matches the assignment
    np.testing.assert_allclose(float(res.objective),
                               dm.min(axis=1).sum(), rtol=1e-5)


def test_objective_decreases_with_budget():
    _, D = _random_instance(1, m=60)
    objs = [float(kmedoids_jax(jnp.asarray(D), k).objective)
            for k in (2, 4, 8, 16, 32)]
    assert all(a >= b - 1e-5 for a, b in zip(objs, objs[1:]))


def test_k_equals_m_gives_zero_objective():
    _, D = _random_instance(2, m=24)
    res = kmedoids_jax(jnp.asarray(D), 24)
    assert float(res.objective) < 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(8, 48),
       k=st.integers(1, 8))
def test_property_invariants(seed, m, k):
    k = min(k, m)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, 4)).astype(np.float32)
    D = np.sqrt(np.maximum(np.asarray(pairwise_sq_dists(jnp.asarray(x))),
                           0.0))
    res = kmedoids_jax(jnp.asarray(D), k)
    meds = np.asarray(res.medoids)
    assert len(set(meds.tolist())) == k
    assert int(np.sum(np.asarray(res.weights))) == m
    # swap solution is no worse than BUILD-only would ever be required:
    # objective is at least the optimum lower bound 0 and finite
    assert 0.0 <= float(res.objective) < 1e9
    # every point's assigned medoid distance <= distance to any medoid
    dm = D[:, meds]
    assigned = dm[np.arange(m), np.asarray(res.assignment)]
    assert np.all(assigned <= dm.min(axis=1) + 1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_medoid_is_own_cluster_member(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(30, 3)).astype(np.float32)
    D = np.sqrt(np.maximum(np.asarray(pairwise_sq_dists(jnp.asarray(x))),
                           0.0))
    res = kmedoids_jax(jnp.asarray(D), 5)
    meds = np.asarray(res.medoids)
    assign = np.asarray(res.assignment)
    for slot, mi in enumerate(meds):
        assert assign[mi] == slot  # each medoid assigned to itself
