"""Data-pipeline tests: generator statistics match Table 1, determinism."""
import numpy as np
import pytest

from repro.data.batching import batch_iterator, epoch_batches
from repro.data.charlm import VOCAB, shakespeare_like_dataset
from repro.data.mnist_like import mnist_like_dataset
from repro.data.partition import power_law_sizes, train_test_split_clients
from repro.data.synthetic import synthetic_dataset


def test_synthetic_shapes_and_determinism():
    a = synthetic_dataset(0.5, 0.5, n_clients=5, mean_samples=100,
                          std_samples=50, seed=7)
    b = synthetic_dataset(0.5, 0.5, n_clients=5, mean_samples=100,
                          std_samples=50, seed=7)
    assert len(a) == 5
    for ca, cb in zip(a, b):
        assert ca["x"].shape[1] == 60
        assert ca["y"].min() >= 0 and ca["y"].max() < 10
        np.testing.assert_array_equal(ca["x"], cb["x"])


def test_synthetic_heterogeneity_increases_with_beta():
    """Higher β => per-client feature means v_i spread further apart."""
    def feature_spread(beta):
        clients = synthetic_dataset(0.0, beta, n_clients=12,
                                    mean_samples=400, std_samples=10, seed=3)
        means = np.stack([c["x"].mean(axis=0) for c in clients])
        return float(np.std(means[:, 0]))
    assert feature_spread(4.0) > feature_spread(0.0)


def test_mnist_like_statistics():
    clients = mnist_like_dataset(n_clients=50, seed=0)
    assert len(clients) == 50
    for c in clients[:10]:
        assert c["x"].shape[1:] == (28, 28)
        assert len(np.unique(c["y"])) <= 2  # 2 digits per client


def test_shakespeare_like():
    clients = shakespeare_like_dataset(n_clients=4, mean_samples=50,
                                       std_samples=20, seq_len=20, seed=0)
    for c in clients:
        assert c["x"].shape[1] == 20
        assert c["x"].max() < VOCAB
        # next-char alignment: y[t] == x[t+1]
        np.testing.assert_array_equal(c["x"][0, 1:], c["y"][0, :-1])


def test_power_law_sizes_match_target():
    rng = np.random.default_rng(0)
    sizes = power_law_sizes(5000, mean=69.0, std=106.0, rng=rng)
    assert abs(sizes.mean() - 69) / 69 < 0.25
    assert sizes.min() >= 8


def test_train_test_split():
    clients = synthetic_dataset(0, 0, n_clients=3, mean_samples=100,
                                std_samples=10, seed=0)
    train, test = train_test_split_clients(clients, test_frac=0.2)
    total_train = sum(len(c["y"]) for c in train)
    total = sum(len(c["y"]) for c in clients)
    assert len(test["y"]) + total_train == total
    assert len(test["y"]) >= 0.15 * total


def test_epoch_batches_cover_everything():
    data = {"x": np.arange(23)[:, None].astype(np.float32),
            "y": np.arange(23)}
    rng = np.random.default_rng(0)
    seen = np.concatenate([b["y"] for b in epoch_batches(data, 8, rng)])
    assert sorted(seen.tolist()) == list(range(23))


def test_batch_iterator_counts_steps():
    data = {"x": np.zeros((10, 2), np.float32), "y": np.zeros(10, np.int64)}
    rng = np.random.default_rng(0)
    batches = list(batch_iterator(data, 4, steps=7, rng=rng))
    assert len(batches) == 7
