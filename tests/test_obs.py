"""Observability layer: recorder/schema/sink contracts + determinism.

Four contracts under test:

1. **Schema** — every runtime (sync server, async event engine, fleet
   loop/batched, async fleet) emits one validating record stream:
   canonical ``round``
   events with the same required fields, aligned ``clients`` events,
   well-nested spans (unique sids, child intervals inside parents).
2. **Coverage** — the phase spans (direct children of each ``round``
   span) account for >= 90% of the round's wall time, so the phase
   timeline in ``benchmarks/report.py`` is an honest decomposition.
3. **Determinism** — recording is observational only: runs with the
   recorder on vs off produce byte-identical params and identical
   histories on every runtime (the recorder touches only the monotonic
   clock, never the RNG or numerics).
4. **Dispatch accounting** — ``DispatchTraceIndexer`` pins the PR 3
   per-(client, dispatch) trace-indexing fix shared by all runtimes,
   and the program-cache/dispatch counters agree with engine state.
"""
import dataclasses
import importlib.util
import io
import json
import os

import jax
import numpy as np
import pytest

from repro.data.partition import train_test_split_clients
from repro.fed.fleet.scenarios import run_scenario
from repro.fed.fleet.workloads import get_workload
from repro.fed.simulator import (CapabilityTrace, ClientSpec,
                                 DispatchTraceIndexer, TraceConfig)
from repro.obs import (NULL_RECORDER, ConsoleSink, InMemorySink, JSONLSink,
                       MetricsRegistry, Recorder, get_recorder, read_jsonl,
                       use_recorder, validate_records)
from repro.obs.sinks import ROUND_FORMATS

RUNTIMES = ("sync", "async", "fleet", "async_fleet")


def _report_mod():
    """Import benchmarks/report.py (not a package) by path."""
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "report.py")
    spec = importlib.util.spec_from_file_location("obs_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def small_fleet():
    wl = get_workload("mlp")
    clients = wl.make_clients(n_clients=8, seed=0)
    train, test = train_test_split_clients(clients, test_frac=0.25)
    return wl, train, test


def _run(runtime, wl, train, test, sinks, **kw):
    rec = Recorder(sinks=list(sinks))
    with use_recorder(rec):
        out = run_scenario("device_classes", runtime, clients_data=train,
                           test_data=test, workload=wl, seed=0, rounds=2,
                           epochs=2, batch_size=8, **kw)
        rec.close()     # flushes the final metrics snapshot
    return out


@pytest.fixture(scope="module")
def recorded_runs(small_fleet):
    """One recorded run per runtime, shared by the schema/coverage/
    report tests."""
    wl, train, test = small_fleet
    runs = {}
    for runtime in RUNTIMES:
        sink = InMemorySink()
        out = _run(runtime, wl, train, test, [sink])
        runs[runtime] = (out, sink.records)
    return runs


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_metrics_registry():
    m = MetricsRegistry()
    m.counter("a").inc()
    m.counter("a").inc(4)
    m.gauge("g").set(2.5)
    h = m.histogram("h")
    for v in (0.5, 3.0, 3.0, 100.0):
        h.observe(v)
    hx = m.histogram("stale", exact=True)
    hx.observe(0)
    hx.observe(0)
    hx.observe(3)
    snap = m.snapshot()
    assert snap["counters"] == {"a": 5}
    assert snap["gauges"] == {"g": 2.5}
    hs = snap["histograms"]["h"]
    assert hs["count"] == 4 and hs["min"] == 0.5 and hs["max"] == 100.0
    assert hs["buckets"]["le_0.5"] == 1
    assert hs["buckets"]["le_4"] == 2      # power-of-2 upper bounds
    assert hs["buckets"]["le_128"] == 1
    assert snap["histograms"]["stale"]["buckets"] == {"0": 2, "3": 1}


def test_null_recorder_is_inert():
    obs = get_recorder()
    assert obs is NULL_RECORDER and not obs.enabled
    obs.event("round", anything=1)
    with obs.span("phase", k=3) as sp:
        sp.attrs["compile"] = True      # writable throwaway
    obs.metrics.counter("x").inc()
    obs.metrics.histogram("y").observe(1.0)
    assert all(not v for v in obs.metrics.snapshot().values())


# ---------------------------------------------------------------------------
# schema + span nesting across every runtime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runtime", RUNTIMES)
def test_schema_validates_per_runtime(recorded_runs, runtime):
    _, records = recorded_runs[runtime]
    validate_records(records)           # envelope + nesting invariants

    rounds = [r for r in records
              if r["kind"] == "event" and r["name"] == "round"]
    assert len(rounds) >= 2
    for r in rounds:
        assert r["data"]["runtime"] == runtime
    clients = [r for r in records
               if r["kind"] == "event" and r["name"] == "clients"]
    assert len(clients) == len(rounds)
    for ev in clients:
        d = ev["data"]
        assert len(d["cids"]) == len(d["durations"]) == len(d["violated"])

    runs = [r for r in records if r["kind"] == "run"]
    assert len(runs) == 1 and runs[0]["data"]["runtime"] == runtime
    snaps = [r for r in records if r["kind"] == "metrics"]
    assert len(snaps) == 1              # rec.close() flushed exactly once
    counters = snaps[-1]["data"]["counters"]
    assert counters["dispatches" if runtime != "fleet"
                    else "fleet.dispatches"] > 0
    if runtime == "async_fleet":
        # client dispatches AND the (fewer) jitted group-program dispatches
        assert 0 < counters["fleet.dispatches"] <= counters["dispatches"]


@pytest.mark.parametrize("runtime", RUNTIMES)
def test_phase_spans_cover_round_wall_time(recorded_runs, runtime):
    """Direct children of each round span sum to >= 90% of its wall."""
    _, records = recorded_runs[runtime]
    rpt = _report_mod()
    (run,) = rpt.load_runs(records)
    rows = [r for r in run.phase_rows() if r["phases"]]
    assert rows, "no instrumented round spans"
    for r in rows:
        assert r["coverage"] >= 0.90, (runtime, r)
    assert run.totals()["phase_coverage_mean"] >= 0.90


def test_jsonl_round_trip(small_fleet, tmp_path):
    """The JSONL file is the in-memory stream, json-normalized."""
    wl, train, test = small_fleet
    path = tmp_path / "run.jsonl"
    mem = InMemorySink()
    _run("fleet", wl, train, test, [mem, JSONLSink(str(path))])
    from_disk = read_jsonl(str(path))
    normalized = [json.loads(json.dumps(r)) for r in mem.records]
    assert from_disk == normalized
    validate_records(from_disk)


def test_console_sink_matches_round_events(small_fleet):
    """Satellite (b): the console line is a pure function of the round
    event — same text the runtimes used to print() directly."""
    wl, train, test = small_fleet
    buf = io.StringIO()
    mem = InMemorySink()
    _run("sync", wl, train, test, [mem, ConsoleSink(stream=buf)])
    rounds = [r["data"] for r in mem.records
              if r["kind"] == "event" and r["name"] == "round"]
    expected = [ROUND_FORMATS[d["runtime"]](d) for d in rounds]
    assert buf.getvalue().splitlines() == expected
    assert expected and expected[0].startswith("[fedcore] round ")


def test_report_cli_renders_and_stamps(small_fleet, tmp_path):
    wl, train, test = small_fleet
    log = tmp_path / "fleet.jsonl"
    bench = tmp_path / "BENCH.json"
    _run("fleet", wl, train, test, [JSONLSink(str(log))])
    bench.write_text(json.dumps({"engine": {"speedup": 5.0}}))
    rpt = _report_mod()
    assert rpt.main([str(log), "--bench-out", str(bench)]) == 0
    stamped = json.loads(bench.read_text())
    assert stamped["engine"] == {"speedup": 5.0}       # merged, not clobbered
    (run,) = stamped["observability"]["runs"]
    assert run["meta"]["runtime"] == "fleet"
    assert run["totals"]["rounds"] == 2
    assert run["phase_wall_s"] and run["top_stragglers"]


# ---------------------------------------------------------------------------
# determinism: recording on == recording off, per runtime/engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runtime,engine", [
    ("sync", None), ("async", None),
    ("fleet", "batched"), ("fleet", "loop"), ("fleet", "sharded"),
    ("async_fleet", "batched"), ("async_fleet", "loop"),
])
def test_recording_preserves_determinism(small_fleet, runtime, engine):
    """Byte-identical params + identical histories with the recorder on
    vs off: recording never touches event ordering, RNG, or numerics."""
    wl, train, test = small_fleet
    kw = {"fleet_engine": engine} if engine else {}

    def go(record):
        if record:
            return _run(runtime, wl, train, test, [InMemorySink()], **kw)
        return run_scenario("device_classes", runtime, clients_data=train,
                            test_data=test, workload=wl, seed=0, rounds=2,
                            epochs=2, batch_size=8, **kw)

    def hist_rows(out):
        rows = []
        for r in out["history"]:
            d = dataclasses.asdict(r)
            # real wall-clock, nondeterministic between any two runs
            # (recording on or off) — everything else must match exactly
            d.pop("wall_time", None)
            rows.append(d)
        return rows

    on, off = go(True), go(False)
    assert hist_rows(on) == hist_rows(off)
    for a, b in zip(jax.tree.leaves(on["params"]),
                    jax.tree.leaves(off["params"])):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    if runtime in ("async", "async_fleet"):
        assert on["event_log"] == off["event_log"]


# ---------------------------------------------------------------------------
# dispatch accounting: the shared trace indexer + cache counters
# ---------------------------------------------------------------------------

def test_trace_indexer_pins_per_dispatch_semantics():
    """Satellite (a): the PR 3 fix, now in one shared helper.  The trace
    is indexed by each client's own dispatch ordinal — a client absent
    for some rounds samples entry k on its k-th *dispatch*, never its
    round number — and the no-trace path is bit-exact (capability is
    spec.c, jitter is exactly 1.0)."""
    specs = [ClientSpec(cid=i, m=16, c=1.0 + i) for i in range(3)]
    trace = CapabilityTrace(TraceConfig(jitter_std=0.2, slowdown_prob=0.5,
                                        seed=7))
    ti = DispatchTraceIndexer(len(specs), trace)
    # client 2 participates only in "rounds" 0 and 2; client 0 in all
    ks = {0: [], 2: []}
    for rnd in range(3):
        for cid in (0, 2) if rnd != 1 else (0,):
            ks[cid].append(ti.begin(cid))
    assert ks[0] == [0, 1, 2]
    assert ks[2] == [0, 1]          # dispatch ordinals, not round numbers
    # the indexer is a pure forwarding wrapper around the trace
    s = specs[2]
    assert ti.capability(s, 1) == trace.capability(s, 1)
    assert ti.jitter(s, 1) == trace.jitter(s, 1)
    # traceless: the identity fast path multiplies by exactly 1.0
    ti0 = DispatchTraceIndexer(len(specs), None)
    assert ti0.capability(s, 5) == s.c
    assert ti0.jitter(s, 5) == 1.0
    d = 123.456
    assert d / ti0.capability(s, 0) * ti0.jitter(s, 0) == d / s.c


def test_program_cache_counters(small_fleet):
    """Round 2 reuses round 1's compiled group programs: misses and
    compiles happen once, later rounds are pure cache hits."""
    wl, train, test = small_fleet
    sink = InMemorySink()
    _run("fleet", wl, train, test, [sink])
    snap = [r for r in sink.records if r["kind"] == "metrics"][-1]["data"]
    c = snap["counters"]
    assert c["program_cache.group.miss"] > 0
    assert c["program_cache.group.hit"] >= c["program_cache.group.miss"]
    assert c["program_cache.compiles"] >= c["program_cache.group.miss"]
    assert c["fleet.dispatches"] > 0
    # every dispatch span carries the compile split
    spans = [r for r in sink.records if r["kind"] == "span"
             and r["name"] in ("local_sgd", "coreset_group")]
    assert spans and all("compile" in s["attrs"] for s in spans)
    assert any(s["attrs"]["compile"] for s in spans)
    assert not all(s["attrs"]["compile"] for s in spans)


def test_scoped_recorder_shares_span_state(tmp_path):
    """scoped() sinks see the same span tree (shared sids/nesting) —
    the async runtime relies on this to tee a window into extra sinks."""
    base, extra = InMemorySink(), InMemorySink()
    rec = Recorder(sinks=[base])
    with rec.span("outer"):
        rec.scoped(extra).event("inner_event", x=1)
        with rec.scoped(extra).span("inner"):
            pass
    validate_records(base.records + [r for r in extra.records
                                     if r not in base.records])
    inner = next(r for r in extra.records
                 if r["kind"] == "span" and r["name"] == "inner")
    outer = next(r for r in base.records
                 if r["kind"] == "span" and r["name"] == "outer")
    assert inner["parent"] == outer["sid"]
    assert inner["depth"] == outer["depth"] + 1
