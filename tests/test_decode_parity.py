"""Decode-vs-forward parity across the non-dense families: running the
full sequence through `decode_step` one token at a time (with the family's
cache/state machinery) must reproduce the training `forward` logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.model import Model


def _roll(model, params, tokens, seq_len, **state_kw):
    st = model.init_decode_state(params, tokens.shape[0], seq_len,
                                 dtype=jnp.float32, **state_kw)
    outs = []
    for t in range(tokens.shape[1]):
        lg, st = model.decode_step(params, st, tokens[:, t:t + 1],
                                   jnp.asarray(t, jnp.int32))
        outs.append(lg)
    return jnp.concatenate(outs, axis=1)


def test_moe_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=50,
                      n_experts=4, moe_capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 50)
    full, _, _ = model.forward(params, {"tokens": tokens}, impl="naive")
    inc = _roll(model, params, tokens, 16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=3e-4, atol=3e-4)


def test_hybrid_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="hybrid", n_layers=3, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=50,
                      ssm_state=8, ssm_headdim=16, ssm_chunk=4,
                      attn_every=2)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 50)
    full, _, _ = model.forward(params, {"tokens": tokens}, impl="naive")
    inc = _roll(model, params, tokens, 8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=3e-4, atol=3e-4)


def test_ssm_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50,
                      ssm_state=8, ssm_headdim=16, ssm_chunk=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 50)
    full, _, _ = model.forward(params, {"tokens": tokens})
    inc = _roll(model, params, tokens, 10)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=3e-4, atol=3e-4)


def test_xlstm_model_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="xlstm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50,
                      xlstm_pattern="ms")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 50)
    full, _, _ = model.forward(params, {"tokens": tokens})
    inc = _roll(model, params, tokens, 10)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=3e-4, atol=3e-4)


def test_audio_decode_matches_forward():
    cfg = ModelConfig(arch_id="t", family="audio", n_layers=2, enc_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      vocab_size=50, act="gelu")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 50)
    enc = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 32))
    full, _, _ = model.forward(params, {"tokens": tokens,
                                        "encoder_embeddings": enc},
                               impl="naive")
    inc = _roll(model, params, tokens, 8, enc_embeddings=enc)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=3e-4, atol=3e-4)
