"""Property + golden tests for the cost-conditioned budget layer.

Three contracts:

  * **Legacy is byte-identical** — ``cost=None`` budgets reproduce the
    exact pre-refactor integer arithmetic (the ×1.0 short-circuit in
    ``WorkloadCostModel``), checked against inline re-implementations of
    the old formulas over a seeded grid.
  * **Budget laws hold for every cost model** — monotone in deadline and
    capability, clipped to [1, m], plan invariants (property tests; run
    under hypothesis when available, otherwise over a seeded random grid
    — the container does not ship hypothesis, so the grid is the CI
    path).
  * **The measured table is sane** — HLO FLOPs per sample for each
    registered workload, pinned within a generous band (XLA flop counts
    drift across versions) plus strict cross-workload ordering, which is
    what budget conditioning actually consumes.
"""
import numpy as np
import pytest

from repro.core.coreset import coreset_budget, needs_coreset
from repro.fed.cost import (FORWARD_FRAC, UNIT_COST, WorkloadCostModel,
                            resolve_cost, workload_cost_model)

try:        # optional: not installed in the CI container
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# case generation: hypothesis when present, seeded grid otherwise
# ---------------------------------------------------------------------------

def _grid_cases(n=2000, seed=0):
    """(m, c, tau, E, kappa) tuples spanning the regimes the formulas see."""
    rng = np.random.default_rng(seed)
    m = rng.integers(1, 400, n)
    c = rng.uniform(0.05, 5.0, n)
    tau = rng.uniform(0.5, 300.0, n)
    E = rng.integers(1, 8, n)
    kappa = rng.choice([1.0, 0.25, 3.7, 91.24, 511.6], n)
    return [(int(m[i]), float(c[i]), float(tau[i]), int(E[i]),
             float(kappa[i])) for i in range(n)]


def for_all_cases(f):
    """Run ``f(m, c, tau, E, kappa)`` for every generated case."""
    if HAVE_HYPOTHESIS:
        @settings(max_examples=500, deadline=None)
        @given(m=st.integers(1, 400), c=st.floats(0.05, 5.0),
               tau=st.floats(0.5, 300.0), E=st.integers(1, 8),
               kappa=st.sampled_from([1.0, 0.25, 3.7, 91.24, 511.6]))
        def run(m, c, tau, E, kappa):
            f(m, c, tau, E, kappa)
        run()
    else:
        for case in _grid_cases():
            f(*case)


# ---------------------------------------------------------------------------
# legacy byte-identity (the seed's formulas, inlined)
# ---------------------------------------------------------------------------

def test_legacy_budget_byte_identical():
    """cost=None reproduces the exact pre-refactor §4.2 arithmetic."""
    def check(m, c, tau, E, _kappa):
        got = coreset_budget(m, c, tau, E)
        want = m if E <= 1 else max(
            1, min(int(np.floor((c * tau - m) / (E - 1))), m))
        assert got == want
        assert needs_coreset(m, c, tau, E) == (E * m > c * tau)
    for_all_cases(check)


def test_legacy_fallback_byte_identical():
    """UNIT_COST.fallback_plan reproduces the seed's §4.4 block."""
    def check(m, c, tau, E, _kappa):
        plan = UNIT_COST.fallback_plan(m, c, tau, E)
        avail = c * tau - FORWARD_FRAC * m
        budget = max(1, min(int(avail // E), m))
        eff = max(1, min(E, int(avail // budget)))
        work = FORWARD_FRAC * m + eff * budget
        assert plan.budget == budget
        assert plan.eff_epochs == eff
        assert plan.work == work
        assert plan.violated == (work > c * tau * (1.0 + 1e-9))
    for_all_cases(check)


def test_nominal_budgets_legacy_unchanged():
    """The fleet driver's vectorized budgets match per-spec coreset_budget
    with and without a cost model."""
    from repro.fed.fleet.batched import nominal_budgets
    from repro.fed.simulator import ClientSpec
    rng = np.random.default_rng(7)
    specs = [ClientSpec(cid=i, m=int(rng.integers(4, 200)),
                        c=float(rng.uniform(0.1, 4.0))) for i in range(64)]
    cm = WorkloadCostModel(name="x", cost_per_sample=3.7, source="manual")
    for cost in (None, cm):
        budgets = nominal_budgets(specs, deadline=40.0, epochs=3, cost=cost)
        r = resolve_cost(cost)
        for s in specs:
            want = (s.m if not r.needs_coreset(s.m, s.c, 40.0, 3)
                    else r.budget(s.m, s.c, 40.0, 3))
            assert budgets[s.cid] == want


# ---------------------------------------------------------------------------
# budget laws for arbitrary cost models
# ---------------------------------------------------------------------------

def test_budget_bounds_and_monotonicity():
    def check(m, c, tau, E, kappa):
        cm = WorkloadCostModel(name="t", cost_per_sample=kappa,
                               source="manual")
        b = cm.budget(m, c, tau, E)
        assert 1 <= b <= m
        # monotone nondecreasing in deadline and in capability
        assert cm.budget(m, c, tau * 1.5, E) >= b
        assert cm.budget(m, c * 1.5, tau, E) >= b
        # more expensive samples never buy a bigger budget
        slow = WorkloadCostModel(name="t2", cost_per_sample=kappa * 2.0,
                                 source="manual")
        assert slow.budget(m, c, tau, E) <= b
    for_all_cases(check)


def test_plan_invariants():
    def check(m, c, tau, E, kappa):
        cm = WorkloadCostModel(name="t", cost_per_sample=kappa,
                               source="manual")
        plan = cm.primary_plan(m, c, tau, E)
        if plan is not None:
            assert not plan.violated
            assert plan.eff_epochs == E
            assert plan.work == m + (E - 1) * plan.budget
            # the primary plan fits inside the deadline by construction
            assert plan.work <= cm.available_samples(c, tau) * (1 + 1e-12)
        fb = cm.fallback_plan(m, c, tau, E)
        assert 1 <= fb.budget <= m
        assert 1 <= fb.eff_epochs <= E
        assert fb.work >= FORWARD_FRAC * m
        if not fb.violated:
            assert cm.work_units(fb.work) <= c * tau * (1.0 + 1e-9)
    for_all_cases(check)


def test_needs_coreset_consistent_with_full_round_time():
    def check(m, c, tau, E, kappa):
        cm = WorkloadCostModel(name="t", cost_per_sample=kappa,
                               source="manual")
        assert cm.needs_coreset(m, c, tau, E) == \
            (cm.full_round_time(m, c, E) > tau)
    for_all_cases(check)


# ---------------------------------------------------------------------------
# resolve_cost + conversions
# ---------------------------------------------------------------------------

def test_resolve_cost():
    assert resolve_cost(None) is UNIT_COST
    cm = WorkloadCostModel(name="x", cost_per_sample=2.0, source="manual")
    assert resolve_cost(cm) is cm
    scalar = resolve_cost(2.5)
    assert scalar.cost_per_sample == 2.5 and scalar.source == "manual"
    with pytest.raises(TypeError):
        resolve_cost("mlp")


def test_unit_conversions():
    cm = WorkloadCostModel(name="x", cost_per_sample=4.0, source="manual")
    # work: samples x kappa; duration: work / capability
    assert cm.work_units(10) == 40.0
    assert cm.duration(10, 2.0) == 20.0
    assert cm.full_round_time(m=10, capability=2.0, epochs=3) == 60.0
    # available samples invert duration: n samples fit in duration(n, c)
    n = cm.available_samples(2.0, 20.0)
    assert np.isclose(cm.duration(n, 2.0), 20.0)
    # the unit model is a passthrough
    assert UNIT_COST.work_units(7) == 7
    assert UNIT_COST.available_samples(3.0, 5.0) == 15.0


# ---------------------------------------------------------------------------
# measured golden table
# ---------------------------------------------------------------------------

# HLO FLOPs per sample for the jitted local-SGD step (batch 8), measured
# on the container's CPU backend.  XLA flop counting drifts across
# versions, hence the wide rtol; the *ordering* below is the strict part.
GOLDEN_FLOPS_PER_SAMPLE = {
    "mlp": 2.66e3,
    "cnn": 8.59e5,
    "charlm": 2.42e5,
    "xlstm": 7.53e5,
    "translm": 1.36e6,
}


@pytest.mark.parametrize("name", sorted(GOLDEN_FLOPS_PER_SAMPLE))
def test_golden_flops_table(name):
    cm = workload_cost_model(name)
    if cm.source != "flops":    # backend without cost_analysis FLOPs
        pytest.skip(f"backend reported no FLOPs (source={cm.source})")
    assert cm.flops_per_sample == pytest.approx(
        GOLDEN_FLOPS_PER_SAMPLE[name], rel=0.5)


def test_measured_cost_ordering():
    """What conditioning consumes: relative cost must rank the workloads
    by arithmetic intensity — every sequence/conv model costs a multiple
    of the flat-feature mlp reference, and the transformer block is the
    most expensive per sample."""
    cms = {n: workload_cost_model(n) for n in GOLDEN_FLOPS_PER_SAMPLE}
    rel = {n: cm.cost_per_sample for n, cm in cms.items()}
    assert rel["mlp"] == pytest.approx(1.0)     # self-normalized reference
    assert min(rel[n] for n in ("cnn", "charlm", "xlstm", "translm")) > 10.0
    assert rel["translm"] > rel["xlstm"] > rel["charlm"]
    # budgets respond: under one deadline the costly workload gets the
    # smaller coreset (deadline sized so mlp fits comfortably while a
    # ~500x-per-sample transformer is pinned at the floor)
    b_cheap = cms["mlp"].budget(50, 1.0, 200.0, 3)
    b_dear = cms["translm"].budget(50, 1.0, 200.0, 3)
    assert b_dear < b_cheap
