"""Hypothesis property tests for the MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig
from repro.models import moe


def _cfg(e, cf, shared=False):
    return ModelConfig(d_model=16, n_heads=4, n_kv_heads=4, d_ff=32,
                       n_experts=e, moe_capacity_factor=cf,
                       use_shared_expert=shared)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1000), e=st.sampled_from([2, 4, 8]),
       tokens=st.integers(4, 24))
def test_dispatch_conserves_or_drops(seed, e, tokens):
    """Every output row is either a routed expert output scaled by its gate
    (gate in (0,1]) or exactly zero (capacity-dropped)."""
    cfg = _cfg(e, cf=0.75, shared=False)
    p = moe.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, tokens, 16))
    y, aux = moe.moe_ffn(p, cfg, x)
    yr = np.asarray(y).reshape(tokens, 16)
    # oracle without drops
    y_or, _ = moe.moe_ffn_dense_oracle(p, cfg, x)
    yo = np.asarray(y_or).reshape(tokens, 16)
    for t in range(tokens):
        dropped = np.allclose(yr[t], 0.0, atol=1e-6)
        matches = np.allclose(yr[t], yo[t], rtol=1e-4, atol=1e-5)
        assert dropped or matches, f"token {t} neither dropped nor routed"
    assert np.isfinite(float(aux))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_generous_capacity_drops_nothing(seed):
    cfg = _cfg(4, cf=8.0, shared=False)
    p = moe.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16))
    y, _ = moe.moe_ffn(p, cfg, x)
    y_or, _ = moe.moe_ffn_dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_or), rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_aux_loss_bounds(seed):
    """Switch aux loss: >= 1 when router collapses is not guaranteed, but
    it is always >= the perfectly-balanced value... we assert the weaker
    invariant: aux >= 0 and aux <= E (probability masses bounded by 1)."""
    cfg = _cfg(8, cf=1.25)
    p = moe.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32, 16)) * 3
    _, aux = moe.moe_ffn(p, cfg, x)
    assert 0.0 <= float(aux) <= cfg.n_experts
