"""Integration test for the multi-pod dry-run driver: runs one real
(arch x shape) combination end-to-end in a subprocess (512 forced host
devices, lower + compile + analyses).  The full 80-combination sweep is the
deliverable run (results/dryrun_*.jsonl); this guards the machinery."""
import json
import os
import subprocess
import sys

import pytest

ROOT = __file__.rsplit("/tests/", 1)[0]


@pytest.mark.parametrize("args,expect_arch", [
    (["--arch", "whisper-tiny", "--shape", "decode_32k",
      "--mesh", "single"], "whisper-tiny"),
    (["--arch", "yi-9b", "--shape", "prefill_32k", "--mesh", "multi"],
     "yi-9b"),
])
def test_dryrun_single_combination(tmp_path, args, expect_arch):
    out = str(tmp_path / "rec.jsonl")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args, "--out", out],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": f"{ROOT}/src",
             "JAX_PLATFORMS": "cpu"},
        cwd=ROOT)
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads(open(out).read().splitlines()[-1])
    assert rec["ok"], rec
    assert rec["arch"] == expect_arch
    assert rec["memory"]["bytes_per_device"] > 0
    assert rec["cost"].get("flops", 0) > 0
    assert "total_bytes" in rec["collectives"]


def _no_xla_flags_env():
    env = {**os.environ, "PYTHONPATH": f"{ROOT}/src", "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    return env


def test_dryrun_import_has_no_device_side_effect():
    """Importing the module as a library must not force 512 host devices
    (the flag is gated to __main__ / explicit opt-in)."""
    res = subprocess.run(
        [sys.executable, "-c",
         "import repro.launch.dryrun, os, jax; "
         "assert '--xla_force_host_platform_device_count' not in "
         "os.environ.get('XLA_FLAGS', ''), os.environ['XLA_FLAGS']; "
         "assert len(jax.devices()) == 1, jax.devices()"],
        capture_output=True, text=True, timeout=120,
        env=_no_xla_flags_env(), cwd=ROOT)
    assert res.returncode == 0, res.stderr[-2000:]


def test_dryrun_import_opt_in_forces_devices():
    """REPRO_DRYRUN_FORCE_DEVICES=N opts library imports into the forced
    device count (what the old import-time side effect provided)."""
    env = _no_xla_flags_env()
    env["REPRO_DRYRUN_FORCE_DEVICES"] = "8"
    res = subprocess.run(
        [sys.executable, "-c",
         "import repro.launch.dryrun, jax; "
         "assert len(jax.devices()) == 8, jax.devices()"],
        capture_output=True, text=True, timeout=120, env=env, cwd=ROOT)
    assert res.returncode == 0, res.stderr[-2000:]
