"""Distance-free selection tests (the ISSUE 10 tentpole contract).

Covers the feature-tiled Pallas kernels against the materializing jnp
oracles (the parity gate: fused tile-by-tile reductions == build-D-then-
reduce), large-M parity in interpret mode (M ∈ {512, 2048} — sizes where
the (C, M, M) stack is the roofline wall the kernels remove), the
padded-lane election regression (zero feature rows are mutually at
distance 0 and must never win a medoid election), the tile-size audit
for tiny cohort groups, and the property that the distance-free and
D-input solver paths select cost-tied medoids.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.kmedoids import (kmedoids_batched, kmedoids_batched_from_feats,
                                 kmedoids_numpy)
from repro.core.coreset import build_coreset_batched
from repro.kernels import ops, ref


def _masked_feats(rng, c, m, f, p_valid=0.8):
    """Random (C, M, F) features with zero-padded invalid rows."""
    x = rng.normal(size=(c, m, f)).astype(np.float32)
    vf = (rng.random((c, m)) < p_valid).astype(np.float32)
    # at least 2 valid rows per lane so instances stay solvable
    vf[:, :2] = 1.0
    x = x * vf[..., None]
    return jnp.asarray(x), jnp.asarray(vf)


# ---------------------------------------------------------------------------
# kernels vs materializing oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,m,f", [(3, 64, 16), (2, 21, 10), (1, 128, 60),
                                   (4, 8, 3), (2, 40, 129)])
def test_build_cost_from_feats_matches_ref(c, m, f):
    rng = np.random.default_rng(c * 100 + m + f)
    x, vf = _masked_feats(rng, c, m, f)
    d_near = jnp.asarray(np.abs(rng.normal(size=(c, m))).astype(np.float32))
    want = ref.kmedoids_build_cost_from_feats_ref(x, d_near, vf)
    got_k = ops.kmedoids_build_cost_from_feats(x, d_near, vf,
                                               use_kernel=True,
                                               interpret=True)
    got_j = ops.kmedoids_build_cost_from_feats(x, d_near, vf,
                                               use_kernel=False)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_j), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    # padded candidates masked to +BIG in-kernel, valid ones finite
    big = np.asarray(got_k)[np.asarray(vf) == 0.0]
    assert (big >= 1e29).all()
    assert (np.asarray(got_k)[np.asarray(vf) > 0.0] < 1e29).all()


@pytest.mark.parametrize("c,m,f,k", [(3, 64, 16, 5), (2, 21, 10, 1),
                                     (1, 128, 60, 16), (4, 32, 7, 3)])
def test_delta_sweep_from_feats_matches_ref(c, m, f, k):
    rng = np.random.default_rng(c * 1000 + m + f + k)
    x, vf = _masked_feats(rng, c, m, f)
    d1 = np.abs(rng.normal(size=(c, m))).astype(np.float32)
    d2 = d1 + np.abs(rng.normal(size=(c, m))).astype(np.float32)
    n_idx = rng.integers(0, k, size=(c, m))
    onehot = jnp.asarray(np.eye(k, dtype=np.float32)[n_idx])
    args = (x, jnp.asarray(d1), jnp.asarray(d2), vf, onehot)
    A_ref, B_ref = ref.kmedoids_delta_sweep_from_feats_ref(*args)
    A_k, B_k = ops.kmedoids_delta_sweep_from_feats(*args, use_kernel=True,
                                                   interpret=True)
    A_j, B_j = ops.kmedoids_delta_sweep_from_feats(*args, use_kernel=False)
    for got in (A_k, A_j):
        np.testing.assert_allclose(np.asarray(got), np.asarray(A_ref),
                                   rtol=1e-5, atol=1e-4)
    for got in (B_k, B_j):
        np.testing.assert_allclose(np.asarray(got), np.asarray(B_ref),
                                   rtol=1e-5, atol=1e-4)
    assert B_k.shape == (c, m, k)     # padded K lanes sliced off
    # padded candidates carry +BIG removal gain — can never win a swap
    assert (np.asarray(A_k)[np.asarray(vf) == 0.0] >= 1e29).all()


@pytest.mark.parametrize("m,block_m", [(512, 256), (2048, 512)])
def test_large_m_parity_interpret(m, block_m):
    """M ∈ {512, 2048} parity of the distance-free kernels vs the
    materializing oracles — the sizes the (C, M, M) stack path can't
    reach.  Larger blocks keep the interpret-mode grid small; the
    materializing oracle needs only one lane's (M, M) at f64-free f32."""
    rng = np.random.default_rng(m)
    c, f, k = 1, 32, 8
    x, vf = _masked_feats(rng, c, m, f, p_valid=0.9)
    d_near = jnp.asarray(np.abs(rng.normal(size=(c, m))).astype(np.float32))
    want = ref.kmedoids_build_cost_from_feats_ref(x, d_near, vf)
    got = ops.kmedoids_build_cost_from_feats(x, d_near, vf, use_kernel=True,
                                             block_m=block_m,
                                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-2)

    d1 = np.abs(rng.normal(size=(c, m))).astype(np.float32)
    d2 = d1 + np.abs(rng.normal(size=(c, m))).astype(np.float32)
    onehot = jnp.asarray(np.eye(k, dtype=np.float32)[
        rng.integers(0, k, size=(c, m))])
    args = (x, jnp.asarray(d1), jnp.asarray(d2), vf, onehot)
    A_ref, B_ref = ref.kmedoids_delta_sweep_from_feats_ref(*args)
    A, B = ops.kmedoids_delta_sweep_from_feats(*args, use_kernel=True,
                                               block_m=block_m,
                                               interpret=True)
    np.testing.assert_allclose(np.asarray(A), np.asarray(A_ref),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B_ref),
                               rtol=1e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# padded-lane election regression (the satellite-2 bug)
# ---------------------------------------------------------------------------

def test_padded_lane_never_wins_medoid_election():
    """Zero-padded rows sit at the origin, mutually at distance 0.  Place
    every valid point at norm r from the origin but 2r from each other:
    without the in-kernel +BIG masking, the origin (a padded lane) is the
    cheapest k = 1 medoid and wins the BUILD argmin.  The masking must
    keep every selected medoid a valid row."""
    r = 5.0
    m, f = 32, 8
    x = np.zeros((1, m, f), np.float32)
    valid = np.zeros((1, m), bool)
    # 6 valid points: ±r on three axes — pairwise distance r·√2 ≈ 7.07,
    # distance to origin r = 5 < 7.07, so origin would win unmasked
    for i, (axis, sign) in enumerate([(0, 1), (0, -1), (1, 1), (1, -1),
                                      (2, 1), (2, -1)]):
        x[0, i, axis] = sign * r
        valid[0, i] = True
    res = kmedoids_batched_from_feats(jnp.asarray(x), jnp.asarray(valid), 1,
                                      max_sweeps=50)
    med = int(np.asarray(res.medoids)[0, 0])
    assert valid[0, med], f"padded lane {med} won the medoid election"
    # and the mostly-padded grid shape: k near the valid count
    res3 = kmedoids_batched_from_feats(jnp.asarray(x), jnp.asarray(valid), 4,
                                       max_sweeps=50)
    meds = np.asarray(res3.medoids)[0]
    assert valid[0, meds].all()
    assert int(np.asarray(res3.weights)[0].sum()) == 6


# ---------------------------------------------------------------------------
# solver parity: distance-free kernel vs jnp fallback vs numpy oracle
# ---------------------------------------------------------------------------

def _feat_instance(rng, kind, m_pad, k, f=5):
    """A masked/padded *feature* instance mirroring the oracle grid of
    ``test_kmedoids_fused`` (plain / clusters / duplicates / mostly
    padded / all valid), with zero-padded rows as the engines produce."""
    if kind == "all_valid":
        m = m_pad
    elif kind == "mostly_padded":
        m = int(rng.integers(max(k, 2), max(k + 1, m_pad // 5)))
    else:
        m = int(rng.integers(max(k, 4), m_pad + 1))
    x = rng.normal(size=(m, f)).astype(np.float32)
    if kind == "clusters" and m >= 6:
        x[: m // 3] += 4.0
        x[m // 3: 2 * m // 3] -= 4.0
    if kind == "duplicates" and m >= 2 * k:
        x[1::2] = x[::2][: len(x[1::2])]
    xp = np.zeros((m_pad, f), np.float32)
    xp[:m] = x
    valid = np.arange(m_pad) < m
    return xp, valid, x


KINDS = ("plain", "clusters", "duplicates", "mostly_padded", "all_valid")


@pytest.mark.parametrize("k", [1, 4, 8])
def test_from_feats_kernel_and_fallback_bit_identical(k):
    """The distance-free solver picks **bit-identical** medoids whether
    its reductions run through the Pallas kernels (interpret) or the
    chunked jnp fallback — same distances, different execution — across
    the masked/padded instance grid, and its objective matches the f64
    host oracle on the true distances."""
    m_pad = 32
    rng = np.random.default_rng(2000 + k)
    xs, valids, trues = [], [], []
    for i in range(15):
        xp, valid, x = _feat_instance(rng, KINDS[i % len(KINDS)], m_pad, k)
        xs.append(xp)
        valids.append(valid)
        trues.append(x)
    feats = jnp.asarray(np.stack(xs))
    valid = jnp.asarray(np.stack(valids))
    res_k = kmedoids_batched_from_feats(feats, valid, k, max_sweeps=100,
                                        use_kernel=True)
    res_j = kmedoids_batched_from_feats(feats, valid, k, max_sweeps=100,
                                        use_kernel=False)
    np.testing.assert_array_equal(np.asarray(res_k.medoids),
                                  np.asarray(res_j.medoids))
    np.testing.assert_array_equal(np.asarray(res_k.weights),
                                  np.asarray(res_j.weights))
    for c, x in enumerate(trues):
        m = x.shape[0]
        meds = np.asarray(res_k.medoids[c])
        assert (meds < m).all()          # never a padded lane
        sq = (x.astype(np.float64) ** 2).sum(-1)
        D64 = np.sqrt(np.maximum(
            sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0))
        np.fill_diagonal(D64, 0.0)
        want = kmedoids_numpy(D64, k, max_sweeps=100)
        got_obj = D64[:, meds].min(axis=1).sum()
        np.testing.assert_allclose(got_obj, float(want.objective),
                                   rtol=1e-4,
                                   err_msg=f"lane {c} "
                                           f"kind={KINDS[c % len(KINDS)]}")
        assert int(np.asarray(res_k.weights[c]).sum()) == m
        assert (np.asarray(res_k.assignment[c])[m:] == -1).all()


def _assert_cost_tied(feats, valid, k):
    """Distance-free and D-input paths select cost-tied medoid sets.
    ``materialize_below=0`` forces streaming even at these small M (the
    adaptive default would materialize below 256 and make this vacuous)."""
    df = build_coreset_batched(feats, valid, k, distance_free=True,
                               materialize_below=0)
    dd = build_coreset_batched(feats, valid, k, distance_free=False)
    x64 = np.asarray(feats, np.float64)
    v = np.asarray(valid)
    for c in range(x64.shape[0]):
        x = x64[c][v[c]]
        sq = (x * x).sum(-1)
        D64 = np.sqrt(np.maximum(
            sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0))
        np.fill_diagonal(D64, 0.0)
        for cs in (df, dd):
            assert v[c][np.asarray(cs.indices[c])].all()
        # medoid indices address the padded stack; D64 the compacted rows
        pos = np.cumsum(v[c]) - 1

        def obj(meds):
            return D64[:, pos[np.asarray(meds)]].min(axis=1).sum()

        np.testing.assert_allclose(obj(df.indices[c]), obj(dd.indices[c]),
                                   rtol=1e-5, atol=1e-5)


def test_distance_free_matches_d_input_seeded_sweep():
    """Seeded fallback for the hypothesis property below (hypothesis is
    an optional dependency): over randomized masked instances, the
    distance-free and D-input solver paths select identical medoids up
    to tied-optima classes — scored as equal objectives on the f64 true
    distances, with every medoid a valid row."""
    rng = np.random.default_rng(0)
    for trial in range(8):
        c = int(rng.integers(1, 4))
        m = int(rng.integers(8, 48))
        f = int(rng.integers(2, 20))
        k = int(rng.integers(1, 6))
        x, vf = _masked_feats(rng, c, m, f)
        valid = np.asarray(vf) > 0
        k = min(k, int(valid.sum(1).min()))
        _assert_cost_tied(x, jnp.asarray(valid), k)


def test_distance_free_matches_d_input_property():
    """Hypothesis form of the tied-optima property (auto-skip when
    hypothesis is absent, like the fleet/MoE property suites)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3),
           st.integers(6, 40), st.integers(2, 16), st.integers(1, 5))
    def prop(seed, c, m, f, k):
        rng = np.random.default_rng(seed)
        x, vf = _masked_feats(rng, c, m, f)
        valid = np.asarray(vf) > 0
        _assert_cost_tied(x, jnp.asarray(valid),
                          min(k, int(valid.sum(1).min())))

    prop()


# ---------------------------------------------------------------------------
# adaptive materialization cutover
# ---------------------------------------------------------------------------

def test_materialize_below_cutover_small_m_bit_identical():
    """Below the adaptive cutover, ``distance_free=True`` materializes
    anyway — streaming's O(k·C·M²·F) recompute FLOPs cost more than the
    few-MB (C, M, M) stack saves — so small-M selection is bit-identical
    to the D-input path (same program, not just cost-tied)."""
    rng = np.random.default_rng(7)
    x, vf = _masked_feats(rng, 3, 40, 8)
    valid = jnp.asarray(np.asarray(vf) > 0)
    df = build_coreset_batched(x, valid, 5, distance_free=True)
    dd = build_coreset_batched(x, valid, 5, distance_free=False)
    np.testing.assert_array_equal(np.asarray(df.indices),
                                  np.asarray(dd.indices))
    np.testing.assert_array_equal(np.asarray(df.weights),
                                  np.asarray(dd.weights))
    # while forcing the cutover to 0 streams (different reduction order:
    # objectives tie, indices may settle on either tied optimum)
    st = build_coreset_batched(x, valid, 5, distance_free=True,
                               materialize_below=0)
    np.testing.assert_allclose(np.asarray(st.objective),
                               np.asarray(dd.objective), rtol=1e-5)


def test_fleet_engine_streams_selection_below_cutover():
    """``FleetConfig.materialize_below=0`` pushes the streaming solver
    through the fused group selection program: the engine's 1-dispatch
    contract holds and the selected coresets are cost-tied with the
    default (adaptively materializing) engine's."""
    from conftest import fixed_size_clients
    from repro.fed.fleet.batched import (FleetConfig, FleetEngine,
                                         make_cohort_groups)

    model, data = fixed_size_clients("mlp", n_clients=4, m=40, seed=2)
    cfg = FleetConfig(epochs=2, batch_size=8, seed=0)
    cids = list(range(len(data)))
    groups = make_cohort_groups(data, cids, {c: 20 for c in cids}, cfg, 0)
    g = groups[0]
    params = model.init(jax.random.PRNGKey(0))

    eng_mat = FleetEngine(model, cfg)
    eng_str = FleetEngine(model, dataclasses.replace(cfg,
                                                     materialize_below=0))
    cs_mat, n_mat = eng_mat.select_group_coresets(params, g, fused=True)
    cs_str, n_str = eng_str.select_group_coresets(params, g, fused=True)
    assert (n_mat, n_str) == (1, 1)
    np.testing.assert_allclose(np.asarray(cs_str.objective),
                               np.asarray(cs_mat.objective), rtol=1e-5)
    for c in range(g.n_clients):
        m = int(g.m[c])
        for cs in (cs_mat, cs_str):
            assert (np.asarray(cs.indices[c]) < m).all()
            assert int(np.asarray(cs.weights[c]).sum()) == m


# ---------------------------------------------------------------------------
# tile-size audit (the satellite-3 double-padding bug)
# ---------------------------------------------------------------------------

def test_feat_blocks_no_double_padding_for_tiny_groups():
    """Interpret mode must size BOTH tiles to the problem: a tiny cohort
    group (M = 32, F = 16) gets (32, 16) tiles and pads F only to 16 —
    not the 64→128-style waste twice (once in M, once in F) the
    always-pad-F-to-128 stack wrappers paid."""
    bm, bk, fmul = ops._feat_blocks(32, 16, 128, 128, interpret=True)
    assert (bm, bk, fmul) == (32, 16, 16)
    bm, bk, fmul = ops._feat_blocks(64, 60, 128, 128, interpret=True)
    assert (bm, bk, fmul) == (64, 64, 64)
    # floors: sub-8 dims keep the (8, ·) minimum f32 tile shape
    bm, bk, fmul = ops._feat_blocks(5, 3, 128, 128, interpret=True)
    assert (bm, bk, fmul) == (8, 8, 8)
    # compiled TPU path keeps lane-aligned 128-multiples on F
    bm, bk, fmul = ops._feat_blocks(32, 16, 128, 128, interpret=False)
    assert fmul == 128 and bk == 128 and bm == 128
    # large F: block_k divides the 128-padded F
    bm, bk, fmul = ops._feat_blocks(256, 200, 128, 128, interpret=False)
    assert fmul == 128 and (-(-200 // 128) * 128) % bk == 0

    # and the wrappers accept the shrunk tiles end to end (M=8, F=3)
    rng = np.random.default_rng(3)
    x, vf = _masked_feats(rng, 2, 8, 3)
    dn = jnp.asarray(np.abs(rng.normal(size=(2, 8))).astype(np.float32))
    got = ops.kmedoids_build_cost_from_feats(x, dn, vf, use_kernel=True,
                                             interpret=True)
    want = ref.kmedoids_build_cost_from_feats_ref(x, dn, vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
