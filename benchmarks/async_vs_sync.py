"""Async vs sync runtimes: simulated makespan-to-target-loss comparison.

Runs {FedAvg, FedCore} × {sync round loop, async event loop} on the
Synthetic(0.5, 0.5) and pseudo-MNIST workloads under a straggler-heavy
client population, and reports for each variant the final accuracy, the
total simulated makespan, and the *makespan-to-target-loss*: the first
virtual time at which test loss reaches the sync-FedAvg baseline's final
loss (× a small tolerance).  The async runs use staleness-discounted
delayed-gradient aggregation by default (``--aggregator`` switches to
FedAsync mixing or FedBuff) and a time-varying capability trace, under
the same *virtual-time* budget as the sync baseline — async wins by
applying more updates per unit time, not by being handed more work.

  PYTHONPATH=src python benchmarks/async_vs_sync.py            # smoke (CPU)
  PYTHONPATH=src python benchmarks/async_vs_sync.py --mode full
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.data.mnist_like import mnist_like_dataset
from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.aggregators import DelayedGradient, FedAsync, FedBuff
from repro.fed.events import AsyncFLConfig, run_federated_async
from repro.fed.server import FLConfig, run_federated
from repro.fed.simulator import TraceConfig, make_client_specs
from repro.fed.strategies import FedAvg, FedCore, LocalTrainer
from repro.models.small import LogisticRegression, SmallCNN

SCALES = {
    "smoke": dict(
        synthetic=dict(n_clients=20, rounds=10, k=4, epochs=5, lr=0.05,
                       mean_samples=100, std_samples=150),
        mnist=dict(n_clients=24, rounds=8, k=4, epochs=3, lr=0.03,
                   mean_samples=60, std_samples=120),
    ),
    "full": dict(
        synthetic=dict(n_clients=30, rounds=40, k=10, epochs=10, lr=0.05,
                       mean_samples=670, std_samples=1148),
        mnist=dict(n_clients=100, rounds=30, k=10, epochs=5, lr=0.03,
                   mean_samples=69, std_samples=106),
    ),
}


@dataclasses.dataclass
class Result:
    name: str
    final_acc: float
    final_loss: float
    makespan: float
    time_to_target: float = float("nan")


def _curve(history) -> Tuple[List[float], List[float]]:
    """(cumulative virtual time, test loss) at every evaluated record."""
    times, losses, t = [], [], 0.0
    for rec in history:
        t += rec.sim_round_time
        if not np.isnan(rec.test_loss):
            times.append(t)
            losses.append(rec.test_loss)
    return times, losses


def _time_to_target(history, target: float) -> float:
    for t, loss in zip(*_curve(history)):
        if loss <= target:
            return t
    return float("inf")


def _workload(bench: str, p: dict, seed: int):
    if bench == "synthetic":
        clients = synthetic_dataset(0.5, 0.5, n_clients=p["n_clients"],
                                    mean_samples=p["mean_samples"],
                                    std_samples=p["std_samples"], seed=seed)
        model = LogisticRegression()
    else:
        clients = mnist_like_dataset(n_clients=p["n_clients"],
                                     mean_samples=p["mean_samples"],
                                     std_samples=p["std_samples"], seed=seed)
        model = SmallCNN()
    train, test = train_test_split_clients(clients, test_frac=0.3)
    specs = make_client_specs([len(d["y"]) for d in train],
                              np.random.default_rng(seed))
    return model, train, test, specs


def run_bench(bench: str, p: dict, straggler_pct: float, aggregator: str,
              seed: int = 0, verbose: bool = False) -> Dict[str, Result]:
    model, train, test, specs = _workload(bench, p, seed)
    budget = p["rounds"] * p["k"]

    def sync(strat_cls):
        cfg = FLConfig(rounds=p["rounds"], clients_per_round=p["k"],
                       epochs=p["epochs"], batch_size=8, lr=p["lr"],
                       straggler_pct=straggler_pct, eval_every=1, seed=seed)
        strat = strat_cls(LocalTrainer(model, cfg.lr, cfg.batch_size))
        return run_federated(model, train, specs, strat, cfg, test,
                             verbose=verbose)

    def async_(strat_cls, time_budget):
        # same virtual-time budget as the sync baseline: async wins by
        # applying more (staleness-discounted) updates per unit time, not
        # by being handed more client work
        cfg = AsyncFLConfig(max_updates=4 * budget,
                            max_virtual_time=time_budget,
                            concurrency=p["k"], epochs=p["epochs"],
                            batch_size=8, lr=p["lr"],
                            straggler_pct=straggler_pct,
                            record_every=p["k"], eval_every=1, seed=seed,
                            trace=TraceConfig(seed=seed))
        strat = strat_cls(LocalTrainer(model, cfg.lr, cfg.batch_size))
        agg = {
            "delayed_grad": lambda: DelayedGradient(server_lr=0.7),
            "fedasync": lambda: FedAsync(mixing=0.6, staleness_exponent=0.5),
            "fedbuff": lambda: FedBuff(buffer_size=max(2, p["k"] // 2)),
        }[aggregator]()
        return run_federated_async(model, train, specs, strat, cfg,
                                   aggregator=agg, test_data=test,
                                   verbose=verbose)

    runs = {"fedavg-sync": sync(FedAvg), "fedcore-sync": sync(FedCore)}
    time_budget = sum(r.sim_round_time
                      for r in runs["fedavg-sync"]["history"])
    runs["fedavg-async"] = async_(FedAvg, time_budget)
    runs["fedcore-async"] = async_(FedCore, time_budget)

    baseline = runs["fedavg-sync"]["history"]
    target = float([r.test_loss for r in baseline
                    if not np.isnan(r.test_loss)][-1]) * 1.05

    results = {}
    for name, out in runs.items():
        hist = out["history"]
        times, losses = _curve(hist)
        accs = [r.test_acc for r in hist if not np.isnan(r.test_acc)]
        if not losses:  # run ended before any evaluated record
            results[name] = Result(name=name, final_acc=float("nan"),
                                   final_loss=float("nan"), makespan=0.0,
                                   time_to_target=float("inf"))
            continue
        results[name] = Result(
            name=name, final_acc=accs[-1], final_loss=losses[-1],
            makespan=times[-1],
            time_to_target=_time_to_target(hist, target))
    return results


def report(bench: str, results: Dict[str, Result], acc_tol: float) -> bool:
    base = results["fedavg-sync"]
    print(f"\n== {bench} (target loss {base.final_loss * 1.05:.4f} "
          f"= 1.05 x sync-FedAvg final)")
    print(f"{'variant':16s} {'acc':>7s} {'loss':>8s} {'makespan':>10s} "
          f"{'t->target':>10s} {'speedup':>8s}")
    for name, r in results.items():
        speedup = (base.time_to_target / r.time_to_target
                   if np.isfinite(r.time_to_target) else float("nan"))
        print(f"{name:16s} {r.final_acc:7.4f} {r.final_loss:8.4f} "
              f"{r.makespan:10.1f} {r.time_to_target:10.1f} "
              f"{speedup:7.2f}x")
    ok = True
    for name in ("fedavg-async", "fedcore-async"):
        r = results[name]
        faster = r.time_to_target < base.time_to_target
        close = r.final_acc >= base.final_acc - acc_tol
        print(f"  [{'PASS' if faster else 'FAIL'}] {name} reaches target "
              f"faster than sync FedAvg "
              f"({r.time_to_target:.1f} < {base.time_to_target:.1f})")
        print(f"  [{'PASS' if close else 'FAIL'}] {name} final acc within "
              f"{acc_tol:.2f} of sync baseline "
              f"({r.final_acc:.4f} vs {base.final_acc:.4f})")
        ok = ok and faster and close
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--bench", default="both",
                    choices=["synthetic", "mnist", "both"])
    ap.add_argument("--stragglers", type=float, default=30.0)
    ap.add_argument("--aggregator", default="delayed_grad",
                    choices=["delayed_grad", "fedasync", "fedbuff"])
    ap.add_argument("--acc-tol", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    benches = (["synthetic", "mnist"] if args.bench == "both"
               else [args.bench])
    ok = True
    for bench in benches:
        p = SCALES[args.mode][bench]
        results = run_bench(bench, p, args.stragglers, args.aggregator,
                            seed=args.seed, verbose=args.verbose)
        ok = report(bench, results, args.acc_tol) and ok
    print(f"\noverall: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
