"""Ablation (ours): is the k-medoids gradient-matching selection actually
doing the work, or would any subset of size bⁱ do?

Swaps FedCore's selection rule (everything else identical — same budgets,
same weighted loss, same schedule) between:
  * kmedoids   — the paper's Eq.(5) solution (weights = cluster sizes)
  * random     — uniform random subset, uniform weights m/b
  * loss_topk  — highest per-sample loss (a loss-based-sampling baseline
                 from the related-work taxonomy, §2), uniform weights m/b
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.flbench import build_world
from repro.core.coreset import Coreset, build_coreset
from repro.core.gradients import grad_features
from repro.fed.server import run_federated, summarize
from repro.fed.strategies import FedCore, LocalTrainer


class AblatedFedCore(FedCore):
    def __init__(self, trainer, rule: str):
        super().__init__(trainer)
        self.rule = rule
        self.name = f"fedcore[{rule}]"

    def _select(self, feats, budget, data, global_params):
        m = feats.shape[0]
        if self.rule == "kmedoids":
            return build_coreset(feats, budget)
        if self.rule == "random":
            idx = np.random.default_rng(0).choice(m, size=budget,
                                                  replace=False)
        elif self.rule == "loss_topk":
            _, metrics = self.trainer.model.loss(global_params, data)
            per = np.asarray(metrics["per_example_loss"])
            idx = np.argsort(-per)[:budget]
        w = np.full(budget, m / budget, np.float32)
        return Coreset(indices=jnp.asarray(idx, jnp.int32),
                       weights=jnp.asarray(w),
                       objective=jnp.asarray(0.0),
                       assignment=jnp.zeros(m, jnp.int32))

    def local_update(self, global_params, data, spec, deadline, epochs,
                     rng):
        # monkey-patch build_coreset path by overriding the module fn call
        import repro.fed.strategies as S
        orig = S.build_coreset
        data_j = {k: jnp.asarray(v) for k, v in data.items()}
        S.build_coreset = lambda feats, budget, **kw: self._select(
            feats, budget, data_j, global_params)
        try:
            return super().local_update(global_params, data, spec, deadline,
                                        epochs, rng)
        finally:
            S.build_coreset = orig


def run(bench: str = "synthetic_1_1", scale: str = "tiny",
        straggler_pct: float = 30.0, seed: int = 0):
    rows = []
    for rule in ("kmedoids", "random", "loss_topk"):
        world = build_world(bench, scale, straggler_pct, seed)
        trainer = LocalTrainer(world.model, world.cfg.lr,
                               world.cfg.batch_size)
        strat = AblatedFedCore(trainer, rule)
        out = run_federated(world.model, world.train, world.specs, strat,
                            world.cfg, world.test)
        s = summarize(out["history"], out["deadline"])
        rows.append({"rule": rule, "acc": s["final_test_acc"],
                     "loss": s["final_train_loss"]})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="synthetic_1_1")
    ap.add_argument("--scale", default="tiny")
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args(argv)
    agg = {}
    for seed in range(args.seeds):
        for r in run(args.bench, args.scale, seed=seed):
            agg.setdefault(r["rule"], []).append(r["acc"])
    print(f"{'selection rule':>14s} {'mean acc':>9s}  (seeds={args.seeds})")
    for rule, accs in agg.items():
        print(f"{rule:>14s} {np.mean(accs):9.4f}")
    return agg


if __name__ == "__main__":
    main()
