"""Render a telemetry report from a JSONL run log (``make report``).

The observability layer (``repro.obs``) writes one JSON record per line:
``run`` metadata, ``span`` phase timings, canonical per-round ``event``
records, per-round ``clients`` events (aligned cid/duration lists), and
``metrics`` snapshots — one schema across the sync server, the async
event engine, and every fleet engine (loop/batched/sharded).  This CLI
turns such a log into the three tables an operator actually wants:

  * **phase timeline** — per round, wall seconds spent in each phase
    (direct children of that round's ``round`` span: cohort_select,
    local_update/local_sgd, selection, coreset_group, aggregate, eval,
    ...), plus a coverage column (phase sum / round wall) that proves
    the spans account for the round;
  * **top-k stragglers** — per-client totals from the ``clients``
    events (simulated busy seconds, dispatches, deadline violations,
    drops), sorted slowest-first;
  * **summary** — run metadata, utilization/violation aggregates, and
    the final metrics snapshot (dispatch + program-cache counters,
    bytes aggregated, busy-time histogram).

``--bench-out`` stamps the same structured summary into
``BENCH_fleet.json`` under ``"observability"`` so the tracked perf
report carries the phase breakdown.  ``--demo`` first produces a small
fleet run log (JSONL sink) and then reports on it — the zero-setup
walkthrough used by CI and the README.

  PYTHONPATH=src python benchmarks/report.py runs/fleet.jsonl
  PYTHONPATH=src python benchmarks/report.py --demo          # self-contained
"""
from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.obs import read_jsonl, validate_records


# ---------------------------------------------------------------------------
# log model: group the flat record stream per run / round / span tree
# ---------------------------------------------------------------------------

class RunLog:
    """One runtime's slice of a JSONL log (records between ``run`` marks)."""

    def __init__(self, meta: Dict[str, Any]):
        self.meta = meta
        self.spans: List[Dict[str, Any]] = []
        self.rounds: List[Dict[str, Any]] = []    # canonical round events
        self.clients: List[Dict[str, Any]] = []   # per-round clients events
        self.events: List[Dict[str, Any]] = []    # everything else
        self.metrics: Optional[Dict[str, Any]] = None   # last snapshot wins

    @property
    def label(self) -> str:
        m = self.meta
        return (f"{m.get('runtime', '?')}/{m.get('engine', '?')} "
                f"n_clients={m.get('n_clients', '?')} "
                f"seed={m.get('seed', '?')}")

    def round_spans(self) -> List[Dict[str, Any]]:
        return [s for s in self.spans if s["name"] == "round"]

    def phase_rows(self) -> List[Dict[str, Any]]:
        """Per round-span: {round, wall_s, coverage, phases: {name: s}}.

        Phases are the *direct* children of the round span (depth +1,
        parent == round sid); nested detail spans (e.g. grad_features
        inside local_update) are charged to their top-level phase once,
        not double-counted.
        """
        rows = []
        by_parent: Dict[int, List[Dict[str, Any]]] = defaultdict(list)
        for s in self.spans:
            if s.get("parent") is not None:
                by_parent[s["parent"]].append(s)
        for rs in self.round_spans():
            phases: Dict[str, float] = defaultdict(float)
            for child in by_parent.get(rs["sid"], ()):
                phases[child["name"]] += child["dur"]
            wall = rs["dur"]
            total = sum(phases.values())
            rows.append({
                "round": rs["attrs"].get("round"),
                "wall_s": wall,
                "phase_s": total,
                "coverage": (total / wall) if wall > 0 else 1.0,
                "phases": dict(phases),
            })
        return rows

    def straggler_rows(self, top_k: int) -> List[Dict[str, Any]]:
        """Per-client totals across every ``clients`` event, slowest
        (highest simulated busy time) first."""
        acc: Dict[int, Dict[str, Any]] = {}
        for ev in self.clients:
            d = ev["data"]
            n = len(d["cids"])
            dropped = d.get("dropped", [False] * n)
            violated = d.get("violated", [False] * n)
            for cid, dur, drop, viol in zip(d["cids"], d["durations"],
                                            dropped, violated):
                row = acc.setdefault(cid, {"cid": cid, "busy_s": 0.0,
                                           "dispatches": 0, "violations": 0,
                                           "drops": 0})
                row["busy_s"] += float(dur)
                row["dispatches"] += 1
                row["violations"] += int(bool(viol))
                row["drops"] += int(bool(drop))
        order = sorted(acc.values(),
                       key=lambda r: (-r["busy_s"], r["cid"]))
        return order[:top_k]

    def totals(self) -> Dict[str, Any]:
        n_disp = n_viol = n_drop = 0
        busy = 0.0
        for ev in self.clients:
            d = ev["data"]
            n = len(d["cids"])
            n_disp += n
            busy += sum(float(x) for x in d["durations"])
            n_viol += sum(map(bool, d.get("violated", [])))
            n_drop += sum(map(bool, d.get("dropped", [])))
        sim = sum(float(r["data"]["sim_round_time"]) for r in self.rounds)
        wall = sum(float(r["data"]["wall_time_s"]) for r in self.rounds)
        prows = self.phase_rows()
        # a window with no phase children at all is the async runtime's
        # trailing (empty) record window, not an uninstrumented round —
        # it has no matching round event and contributes no coverage
        cov = ([r["coverage"] for r in prows
                if r["phases"] and r["wall_s"] > 0])
        return {
            "rounds": len(self.rounds),
            "client_dispatches": n_disp,
            "deadline_violations": n_viol,
            "drops": n_drop,
            "violation_rate": (n_viol / n_disp) if n_disp else 0.0,
            "drop_rate": (n_drop / n_disp) if n_disp else 0.0,
            "busy_virtual_s": busy,
            "sim_time_s": sim,
            "wall_time_s": wall,
            # cohort-parallel utilization: mean client busy time over the
            # round's critical path (1.0 = perfectly balanced cohort)
            "utilization": (busy / n_disp / (sim / len(self.rounds))
                            if n_disp and sim > 0 else 0.0),
            "phase_coverage_mean": (sum(cov) / len(cov)) if cov else 0.0,
        }


def load_runs(records: List[Dict[str, Any]]) -> List[RunLog]:
    """Split a record stream into per-run slices.

    Records before the first ``run`` mark (e.g. the ``scenario`` event
    ``run_scenario`` stamps) attach to the *next* run; a log with no
    ``run`` record at all becomes one anonymous run.
    """
    runs: List[RunLog] = []
    pending: List[Dict[str, Any]] = []

    def sink(rec: Dict[str, Any], run: Optional[RunLog]) -> None:
        if run is None:
            pending.append(rec)
            return
        kind = rec["kind"]
        if kind == "span":
            run.spans.append(rec)
        elif kind == "metrics":
            run.metrics = rec["data"]
        elif kind == "event" and rec["name"] == "round":
            run.rounds.append(rec)
        elif kind == "event" and rec["name"] == "clients":
            run.clients.append(rec)
        else:
            run.events.append(rec)

    current: Optional[RunLog] = None
    for rec in records:
        if rec["kind"] == "run":
            current = RunLog(rec["data"])
            runs.append(current)
            for p in pending:
                sink(p, current)
            pending = []
        else:
            sink(rec, current)
    if pending:     # headless log: no run record at all
        current = RunLog({})
        runs.append(current)
        for p in pending:
            sink(p, current)
    return runs


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def _fmt_table(headers: List[str], rows: List[List[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.rjust(w) for c, w in zip(cells, widths))
    out = [line(headers), line(["-" * w for w in widths])]
    out += [line(r) for r in rows]
    return "\n".join(out)


def render_run(run: RunLog, top_k: int) -> str:
    out = [f"== run: {run.label}"]
    for k, v in sorted(run.meta.items()):
        if k not in ("runtime", "engine", "n_clients", "seed"):
            out.append(f"   {k}: {v}")

    prows = run.phase_rows()
    # column order: first appearance across the run
    phase_names: List[str] = []
    for r in prows:
        for name in r["phases"]:
            if name not in phase_names:
                phase_names.append(name)
    if prows:
        headers = ["round"] + phase_names + ["other", "wall_s", "cover"]
        body = []
        for r in prows:
            other = r["wall_s"] - r["phase_s"]
            body.append(
                [str(r["round"]) if r["round"] is not None else "-"]
                + [f"{r['phases'].get(n, 0.0):.3f}" for n in phase_names]
                + [f"{max(other, 0.0):.3f}", f"{r['wall_s']:.3f}",
                   f"{r['coverage']:5.1%}"])
        out += ["", "-- phase timeline (wall seconds per round) --",
                _fmt_table(headers, body)]

    srows = run.straggler_rows(top_k)
    if srows:
        headers = ["cid", "busy_virtual_s", "dispatches", "violations",
                   "drops"]
        body = [[str(r["cid"]), f"{r['busy_s']:.1f}", str(r["dispatches"]),
                 str(r["violations"]), str(r["drops"])] for r in srows]
        out += ["", f"-- top-{len(srows)} stragglers (simulated busy "
                    f"time) --", _fmt_table(headers, body)]

    t = run.totals()
    out += ["", "-- summary --"]
    out.append(f"   rounds {t['rounds']}  client dispatches "
               f"{t['client_dispatches']}  violations "
               f"{t['deadline_violations']} "
               f"({t['violation_rate']:.1%})  drops {t['drops']} "
               f"({t['drop_rate']:.1%})")
    out.append(f"   virtual: busy {t['busy_virtual_s']:.1f}s over "
               f"{t['sim_time_s']:.1f}s simulated  "
               f"(utilization {t['utilization']:.1%})")
    out.append(f"   wall: {t['wall_time_s']:.3f}s  phase coverage "
               f"{t['phase_coverage_mean']:.1%}")
    if run.metrics:
        c = run.metrics.get("counters", {})
        if c:
            out.append("   counters: " + "  ".join(
                f"{k}={v}" for k, v in sorted(c.items())))
        h = run.metrics.get("histograms", {})
        if "client_busy_s" in h:
            s = h["client_busy_s"]
            out.append(f"   client busy time: n={s['count']} "
                       f"min={s['min']:.1f}s max={s['max']:.1f}s "
                       f"mean={s['sum'] / max(s['count'], 1):.1f}s")
    return "\n".join(out)


def summarize(runs: List[RunLog], top_k: int) -> List[Dict[str, Any]]:
    """The structured form stamped into BENCH_fleet.json."""
    out = []
    for run in runs:
        prows = run.phase_rows()
        phase_wall: Dict[str, float] = defaultdict(float)
        for r in prows:
            for name, s in r["phases"].items():
                phase_wall[name] += s
        out.append({
            "meta": run.meta,
            "totals": run.totals(),
            "phase_wall_s": dict(sorted(phase_wall.items())),
            "top_stragglers": run.straggler_rows(top_k),
            "counters": (run.metrics or {}).get("counters", {}),
        })
    return out


# ---------------------------------------------------------------------------
# demo mode: produce a small fleet JSONL log, then report on it
# ---------------------------------------------------------------------------

def make_demo_log(path: str, *, rounds: int = 3, n_clients: int = 24,
                  seed: int = 0) -> str:
    from repro.data.partition import train_test_split_clients
    from repro.fed.fleet.scenarios import run_scenario
    from repro.fed.fleet.workloads import get_workload
    from repro.obs import JSONLSink, Recorder, use_recorder

    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    wl = get_workload("mlp")
    clients = wl.make_clients(n_clients=n_clients, seed=seed)
    train, test = train_test_split_clients(clients, test_frac=0.2)
    rec = Recorder(sinks=[JSONLSink(path)])
    with use_recorder(rec):
        run_scenario("device_classes", "fleet", clients_data=train,
                     test_data=test, workload=wl, seed=seed,
                     rounds=rounds, epochs=2, batch_size=8)
        rec.close()
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render a telemetry report from a repro.obs JSONL log")
    ap.add_argument("log", nargs="?", default=None,
                    help="path to a JSONL run log (repro.obs.JSONLSink)")
    ap.add_argument("--demo", action="store_true",
                    help="first produce a small fleet run log "
                         "(runs/obs_demo.jsonl unless a path is given), "
                         "then report on it")
    ap.add_argument("--top-k", type=int, default=5,
                    help="stragglers to list per run (default 5)")
    ap.add_argument("--bench-out", default=None, metavar="BENCH_JSON",
                    help="merge the structured summary into this "
                         "BENCH_fleet.json under 'observability'")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation of the log")
    args = ap.parse_args(argv)

    path = args.log
    if args.demo:
        path = path or os.path.join("runs", "obs_demo.jsonl")
        print(f"producing demo fleet log: {path}")
        make_demo_log(path)
    if path is None:
        ap.error("either a log path or --demo is required")

    records = read_jsonl(path)
    if not records:
        print(f"{path}: empty log")
        return 1
    if not args.no_validate:
        validate_records(records)
        print(f"{path}: {len(records)} records, schema OK")

    runs = load_runs(records)
    for run in runs:
        print()
        print(render_run(run, args.top_k))

    if args.bench_out:
        summary = summarize(runs, args.top_k)
        merged: Dict[str, Any] = {}
        if os.path.exists(args.bench_out):
            try:
                with open(args.bench_out) as f:
                    merged = json.load(f)
            except (OSError, json.JSONDecodeError):
                merged = {}
        merged["observability"] = {"source": os.path.basename(path),
                                   "runs": summary}
        with open(args.bench_out, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
        print(f"\nstamped observability summary into {args.bench_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
