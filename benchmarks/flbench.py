"""Shared FL-benchmark harness for the paper's evaluation (§6).

Builds the three benchmark worlds (pseudo-MNIST / Shakespeare-like /
Synthetic(α,β)) at a chosen scale, runs the four strategies under a
straggler setting, and returns per-round histories + Table-2-style
summaries.  ``scale`` controls cost:

  tiny   — CI scale (runs in benchmarks.run on 1 CPU core)
  small  — a few minutes per cell
  paper  — the published client counts / rounds (Table 1 / Table 3)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.data.charlm import VOCAB, shakespeare_like_dataset
from repro.data.mnist_like import mnist_like_dataset
from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.server import FLConfig, run_federated, summarize
from repro.fed.simulator import make_client_specs
from repro.fed.strategies import (FedAvg, FedAvgDS, FedCore, FedProx,
                                  LocalTrainer)
from repro.models.small import CharLSTM, LogisticRegression, SmallCNN

SCALES = {
    # (n_clients, mean_samples, rounds, clients_per_round, epochs)
    "tiny":  dict(frac_clients=0.02, rounds=6, k=5, epochs=5),
    "small": dict(frac_clients=0.1, rounds=20, k=10, epochs=10),
    "paper": dict(frac_clients=1.0, rounds=100, k=100, epochs=10),
}

# paper Table 1 / Table 3 constants
BENCH_DEFS = {
    "mnist": dict(n_clients=1000, mean=69, std=106, lr=0.03, rounds=100,
                  k=100),
    "shakespeare": dict(n_clients=143, mean=3616, std=6808, lr=0.03,
                        rounds=30, k=10),
    "synthetic_1_1": dict(n_clients=30, mean=670, std=1148, lr=0.001,
                          rounds=100, k=10, alpha=1.0, beta=1.0),
    "synthetic_0505": dict(n_clients=30, mean=670, std=1148, lr=0.001,
                           rounds=100, k=10, alpha=0.5, beta=0.5),
    "synthetic_0_0": dict(n_clients=30, mean=670, std=1148, lr=0.001,
                          rounds=100, k=10, alpha=0.0, beta=0.0),
}

FEDPROX_MU = {"mnist": 0.1, "shakespeare": 0.001, "synthetic_1_1": 0.1,
              "synthetic_0505": 0.1, "synthetic_0_0": 0.1}


@dataclasses.dataclass
class World:
    name: str
    model: object
    train: list
    test: dict
    specs: list
    cfg: FLConfig
    prox_mu: float


def build_world(bench: str, scale: str = "tiny", straggler_pct: float = 30.0,
                seed: int = 0) -> World:
    bd = BENCH_DEFS[bench]
    sc = SCALES[scale]
    n_clients = max(6, int(bd["n_clients"] * sc["frac_clients"]))
    rng = np.random.default_rng(seed)

    if bench == "mnist":
        mean = bd["mean"] if scale == "paper" else max(30, bd["mean"] // 2)
        clients = mnist_like_dataset(n_clients=n_clients, mean_samples=mean,
                                     std_samples=bd["std"] / 2, seed=seed)
        model = SmallCNN()
        lr = bd["lr"]
    elif bench == "shakespeare":
        mean = bd["mean"] if scale == "paper" else 120
        clients = shakespeare_like_dataset(
            n_clients=n_clients, mean_samples=mean, std_samples=mean,
            seq_len=80 if scale == "paper" else 24, seed=seed)
        model = CharLSTM(vocab=VOCAB,
                         d_hidden=128 if scale == "paper" else 48)
        lr = bd["lr"]
    else:
        mean = bd["mean"] if scale == "paper" else 120
        clients = synthetic_dataset(bd["alpha"], bd["beta"],
                                    n_clients=n_clients, mean_samples=mean,
                                    std_samples=mean, seed=seed)
        model = LogisticRegression()
        lr = 0.05 if scale != "paper" else bd["lr"]

    train, test = train_test_split_clients(clients,
                                           rng=np.random.default_rng(seed))
    specs = make_client_specs([len(next(iter(d.values()))) for d in train],
                              rng)
    rounds = bd["rounds"] if scale == "paper" else sc["rounds"]
    k = min(bd["k"] if scale == "paper" else sc["k"], n_clients)
    cfg = FLConfig(rounds=rounds, clients_per_round=k,
                   epochs=10 if scale == "paper" else sc["epochs"],
                   batch_size=8, lr=lr, straggler_pct=straggler_pct,
                   seed=seed, eval_every=max(1, rounds // 5))
    # LSTM/CNN use x/y keys; LocalTrainer is model-agnostic
    return World(bench, model, train, test, specs, cfg,
                 FEDPROX_MU.get(bench, 0.1))


STRATEGY_NAMES = ("fedavg", "fedavg_ds", "fedprox", "fedcore")


def make_strategy(name: str, world: World):
    if name == "fedprox":
        trainer = LocalTrainer(world.model, world.cfg.lr,
                               world.cfg.batch_size, prox_mu=world.prox_mu)
        return FedProx(trainer)
    trainer = LocalTrainer(world.model, world.cfg.lr, world.cfg.batch_size)
    return {"fedavg": FedAvg, "fedavg_ds": FedAvgDS,
            "fedcore": FedCore}[name](trainer)


def run_benchmark(bench: str, scale: str = "tiny",
                  straggler_pct: float = 30.0, seed: int = 0,
                  strategies=STRATEGY_NAMES,
                  verbose: bool = False) -> Dict[str, dict]:
    world = build_world(bench, scale, straggler_pct, seed)
    out = {}
    for name in strategies:
        strat = make_strategy(name, world)
        res = run_federated(world.model, world.train, world.specs, strat,
                            world.cfg, world.test, verbose=verbose)
        res["summary"] = summarize(res["history"], res["deadline"])
        out[name] = res
    return out
