"""Fig. 3 / Fig. 6: training-loss and test-accuracy convergence curves for
FedAvg-DS / FedProx / FedCore (CSV per round)."""
from __future__ import annotations

import argparse

from benchmarks.flbench import run_benchmark


def run(bench: str = "synthetic_1_1", scale: str = "tiny",
        straggler_pct: float = 30.0, seed: int = 0):
    res = run_benchmark(bench, scale, straggler_pct, seed,
                        strategies=("fedavg_ds", "fedprox", "fedcore"))
    curves = {}
    for name, out in res.items():
        curves[name] = [
            {"round": h.round, "train_loss": h.train_loss,
             "test_acc": h.test_acc,
             "sim_time": h.sim_round_time}
            for h in out["history"]]
    return curves


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="synthetic_1_1")
    ap.add_argument("--scale", default="tiny")
    ap.add_argument("--stragglers", type=float, default=30.0)
    args = ap.parse_args(argv)
    curves = run(args.bench, args.scale, args.stragglers)
    print("strategy,round,train_loss,test_acc,cum_sim_time")
    for name, rows in curves.items():
        cum = 0.0
        for r in rows:
            cum += r["sim_time"]
            acc = "" if r["test_acc"] != r["test_acc"] else \
                f"{r['test_acc']:.4f}"
            print(f"{name},{r['round']},{r['train_loss']:.4f},{acc},"
                  f"{cum:.1f}")
    return curves


if __name__ == "__main__":
    main()
