"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), TPU v5e targets:

    compute    = FLOPs / (chips * 197e12 FLOP/s bf16)
    memory     = HBM bytes / (chips * 819e9 B/s)
    collective = collective bytes per chip / (50e9 B/s per ICI link)

Sources:
  * The dry-run JSONL (launch/dryrun.py) supplies compiled
    memory_analysis, raw cost_analysis and HLO-parsed collective bytes.
  * XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE — the
    layer stacks, SSD chunk scans and recurrent scans are undercounted by
    their trip counts.  The roofline terms therefore come from the ANALYTIC
    model below (explicit napkin math per family), cross-validated against
    cost_analysis on small UNROLLED configs in tests/test_roofline.py; the
    raw HLO numbers are carried alongside for transparency.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline --dryrun results/dryrun_single.jsonl
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any, Dict, Optional

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link
BYTES = 2                # bf16


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------

def _attn_flops_fwd(cfg: ModelConfig, batch: int, s_q: int, s_kv: int,
                    causal: bool = True) -> float:
    """QK^T + PV for one layer (window-aware)."""
    w = cfg.attention_window
    eff = min(s_kv, w) if w else s_kv
    if causal and not w and s_q == s_kv:
        eff_avg = s_kv / 2
    else:
        eff_avg = eff
    return 4.0 * batch * s_q * eff_avg * cfg.n_heads * cfg.d_head


def _ffn_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    d, f = cfg.d_model, cfg.d_ff
    mults = 3 if cfg.act == "silu" else 2
    if cfg.n_experts:
        per_tok = cfg.moe_top_k * mults * d * f
        if cfg.use_shared_expert:
            per_tok += mults * d * f
        per_tok += d * cfg.n_experts  # router
        return 2.0 * tokens * per_tok
    return 2.0 * tokens * mults * d * f


def _attn_proj_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return 2.0 * tokens * d * (hq * hd + 2 * hk * hd + hq * hd)


def _mamba_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = 2.0 * tokens * d * (2 * di + 2 * n + nh) + 2.0 * tokens * di * d
    conv = 2.0 * tokens * (di + 2 * n) * cfg.ssm_conv
    # SSD: state update + output, linear in S
    ssd = 2.0 * tokens * 2 * di * n
    # intra-chunk quadratic term (chunk Q): ~2 * tokens * Q * (n + hd)
    q = cfg.ssm_chunk
    ssd += 2.0 * tokens * q * (n + cfg.ssm_headdim) / 2
    return proj + conv + ssd


def _xlstm_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    d = cfg.d_model
    hd = d // cfg.n_heads
    # mLSTM: qkv/o-gate/out projections + matrix-memory update (C, n, Cq)
    m = 2.0 * tokens * (5 * d * d) + 2.0 * tokens * cfg.n_heads * hd * hd * 3
    # sLSTM: input proj (4 gates) + out proj + block-diag recurrent (4 gates)
    s = 2.0 * tokens * (4 * d * d + d * d) + \
        2.0 * tokens * cfg.n_heads * 4 * hd * hd
    return (m + s) / 2  # alternating pattern


def _unembed_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    return 2.0 * tokens * cfg.d_model * cfg.vocab_size


def forward_flops(cfg: ModelConfig, batch: int, seq: int,
                  decode_cache: Optional[int] = None) -> float:
    """Global forward FLOPs.  decode_cache!=None => one-token decode."""
    if decode_cache is not None:
        tokens = float(batch)
        s_q, s_kv = 1, decode_cache
        causal = False
    else:
        tokens = float(batch) * seq
        s_q = s_kv = seq
        causal = True

    total = _unembed_flops_fwd(cfg, tokens)
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        total += L * (_attn_proj_flops_fwd(cfg, tokens)
                      + _attn_flops_fwd(cfg, batch, s_q, s_kv, causal)
                      + _ffn_flops_fwd(cfg, tokens))
    elif cfg.family == "audio":
        enc_tokens = tokens  # encoder seq comparable scale
        total += cfg.enc_layers * (_attn_proj_flops_fwd(cfg, enc_tokens)
                                   + _attn_flops_fwd(cfg, batch,
                                                     s_q, s_kv, False)
                                   + _ffn_flops_fwd(cfg, enc_tokens))
        total += L * (2 * _attn_proj_flops_fwd(cfg, tokens)
                      + 2 * _attn_flops_fwd(cfg, batch, s_q, s_kv, causal)
                      + _ffn_flops_fwd(cfg, tokens))
    elif cfg.family in ("ssm", "hybrid"):
        total += L * _mamba_flops_fwd(cfg, tokens)
        if cfg.family == "hybrid" and cfg.attn_every:
            n_attn = L // cfg.attn_every
            total += n_attn * (
                _attn_proj_flops_fwd(cfg, tokens)
                + _attn_flops_fwd(cfg, batch, s_q, s_kv, causal)
                + _ffn_flops_fwd(cfg, tokens)
                + 2.0 * tokens * 2 * cfg.d_model * cfg.d_model)  # concat proj
    elif cfg.family == "xlstm":
        total += L * _xlstm_flops_fwd(cfg, tokens)
    return total


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
              remat: bool = False, optimizer: str = "sgd") -> float:
    """Global HBM traffic per step (read+write), bf16 params/activations."""
    n_params = cfg.param_count()
    tokens = shape.global_batch * shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers + cfg.enc_layers
    if shape.kind == "train":
        # params read (fwd+bwd) + grad write + optimizer read/write
        opt_mult = 6 if optimizer == "adam" else 4
        p_traffic = opt_mult * n_params * BYTES
        # activations: write fwd, read bwd, ~6 tensors of (tokens, d)/layer
        act_per_layer = 6 * tokens * d * BYTES
        if remat:
            act_per_layer = 2 * tokens * d * BYTES  # only residual saved
            p_traffic += 2 * n_params * BYTES       # recompute re-reads
        return p_traffic + L * act_per_layer
    if shape.kind == "prefill":
        return n_params * BYTES + L * 4 * tokens * d * BYTES
    # decode: weights once + cache read/write
    active = cfg.active_param_count()
    cache = decode_cache_bytes(cfg, shape)
    return active * BYTES + cache


def decode_cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    b, s = shape.global_batch, shape.seq_len
    w = cfg.attention_window
    s_eff = min(s, w) if w else s
    total = 0.0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        total += (cfg.n_layers * b * s_eff * cfg.n_kv_heads * cfg.d_head
                  * 2 * BYTES)
    if cfg.family in ("ssm", "hybrid"):
        total += (cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_headdim
                  * cfg.ssm_state * BYTES)
        if cfg.family == "hybrid" and cfg.attn_every:
            total += (cfg.n_layers // cfg.attn_every) * b * s_eff \
                * cfg.n_kv_heads * cfg.d_head * 2 * BYTES
    if cfg.family == "xlstm":
        hd = cfg.d_model // cfg.n_heads
        total += cfg.n_layers * b * cfg.n_heads * (hd * hd + 2 * hd) * BYTES
    return total


def collective_bytes_per_chip(cfg: ModelConfig, shape: ShapeConfig,
                              mesh_shape: Dict[str, int],
                              sharding: str = "tp",
                              grad_bytes: float = BYTES
                              ) -> Dict[str, float]:
    """Analytic per-chip collective traffic per step (ring terms).

    TP (Megatron-style): 2 activation all-reduces per layer fwd (+2 bwd for
    train), each moving 2*(k-1)/k * local bytes per chip.
    DP (train): gradient all-reduce of the params, 2*(dp-1)/dp * params/chip.
    MoE: all-to-all dispatch+combine of local tokens.
    Multi-pod: the DP term factorizes hierarchically; the pod axis share is
    reported as `dcn_bytes` (crosses the slower inter-pod links).
    """
    k = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = k * dp
    tokens_local = shape.global_batch * (
        1 if shape.kind == "decode" else shape.seq_len) / dp
    d = cfg.d_model
    L = cfg.n_layers + cfg.enc_layers
    ring = lambda n: 2 * (n - 1) / n if n > 1 else 0.0

    # all-reduces per layer per direction (Megatron column->row pairs):
    # attention (1) + mlp (1) = 2 for transformer layers; mamba2's
    # in_proj->out_proj pair = 1 (sharding.py TP-shards w_in/w_out);
    # the zamba2 shared attention block adds 2 per invocation.
    if cfg.family in ("ssm", "hybrid"):
        n_ar_per_layer = 1
    else:
        n_ar_per_layer = 2
    L_attn = (cfg.n_layers // cfg.attn_every
              if cfg.family == "hybrid" and cfg.attn_every else 0)
    fwd_bwd = 2 if shape.kind == "train" else 1

    tp_bytes = (n_ar_per_layer * L + 2 * L_attn) * fwd_bwd * \
        tokens_local * d * BYTES * ring(k)

    dp_bytes = 0.0
    dcn_bytes = 0.0
    if shape.kind == "train":
        sharded_fraction = 1.0 / k  # TP-sharded params all-reduce over dp
        # grad_bytes < BYTES models gradient compression (H2 iter 3: fp8=1)
        grad_local = cfg.param_count() * grad_bytes * sharded_fraction
        dp_bytes = grad_local * ring(dp)
        if mesh_shape.get("pod", 1) > 1:
            dcn_bytes = grad_local * ring(mesh_shape["pod"])

    a2a_bytes = 0.0
    if cfg.n_experts:
        # dispatch + combine, each ~local tokens * d, all-to-all ~ (k-1)/k
        a2a_bytes = 2 * fwd_bwd * tokens_local * d * BYTES * (k - 1) / k

    return {"tp": tp_bytes, "dp": dp_bytes, "a2a": a2a_bytes,
            "dcn": dcn_bytes,
            "total": tp_bytes + dp_bytes + a2a_bytes}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token


def roofline(arch_id: str, shape_name: str, mesh_shape: Dict[str, int],
             sharding: str = "tp", remat: bool = False,
             optimizer: str = "sgd",
             dryrun_record: Optional[Dict[str, Any]] = None
             ) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = get_config(arch_id, shape=shape)
    chips = 1
    for v in mesh_shape.values():
        chips *= v

    if shape.kind == "train":
        flops = 3.0 * forward_flops(cfg, shape.global_batch, shape.seq_len)
    elif shape.kind == "prefill":
        flops = forward_flops(cfg, shape.global_batch, shape.seq_len)
    else:
        flops = forward_flops(cfg, shape.global_batch, shape.seq_len,
                              decode_cache=shape.seq_len)

    hbm = hbm_bytes(cfg, shape, chips, remat=remat, optimizer=optimizer)
    coll = collective_bytes_per_chip(cfg, shape, mesh_shape, sharding)

    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = coll["total"] / ICI_BW

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh_shape.values()),
        "chips": chips,
        "flops": flops, "hbm_bytes": hbm,
        "collective_bytes_per_chip": coll,
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_flops_ratio": mf / flops if flops else float("nan"),
        "step_time_lower_bound_s": max(terms.values()),
        "mfu_upper_bound": mf / (max(terms.values()) * chips * PEAK_FLOPS)
        if max(terms.values()) > 0 else float("nan"),
    }
    if dryrun_record:
        rec["hlo_flops_raw"] = dryrun_record.get("cost", {}).get("flops")
        rec["hlo_collective_bytes_raw"] = dryrun_record.get(
            "collectives", {}).get("total_bytes")
        rec["bytes_per_device_compiled"] = dryrun_record.get(
            "memory", {}).get("bytes_per_device")
    return rec


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def _fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.2f}us"


def build_table(dryrun_path: Optional[str] = None,
                mesh_shape: Optional[Dict[str, int]] = None) -> str:
    mesh_shape = mesh_shape or {"data": 16, "model": 16}
    dr = {}
    if dryrun_path:
        with open(dryrun_path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    dr[(r["arch"], r["shape"])] = r
    rows = []
    header = (f"{'arch':28s} {'shape':12s} {'compute':9s} {'memory':9s} "
              f"{'coll':9s} {'dominant':10s} {'useful%':8s} {'mem/dev':9s}")
    rows.append(header)
    rows.append("-" * len(header))
    for arch in ARCH_IDS:
        for shape in SHAPES:
            rec = roofline(arch, shape, mesh_shape,
                           dryrun_record=dr.get((arch, shape)))
            mem_dev = rec.get("bytes_per_device_compiled")
            mem_str = (f"{mem_dev/2**30:7.1f}Gi" if mem_dev else "      - ")
            rows.append(
                f"{arch:28s} {shape:12s} {_fmt_t(rec['compute_s'])} "
                f"{_fmt_t(rec['memory_s'])} {_fmt_t(rec['collective_s'])} "
                f"{rec['dominant']:10s} "
                f"{100*rec['useful_flops_ratio']:7.1f}% {mem_str}")
    return "\n".join(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default=None,
                    help="dry-run JSONL to join against")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None, help="write records as JSONL")
    args = ap.parse_args(argv)
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if args.multi_pod
                  else {"data": 16, "model": 16})
    print(build_table(args.dryrun, mesh_shape))
    if args.json:
        with open(args.json, "w") as f:
            for arch in ARCH_IDS:
                for shape in SHAPES:
                    f.write(json.dumps(roofline(arch, shape, mesh_shape))
                            + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
