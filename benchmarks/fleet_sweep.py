"""Fleet-scale sweep: batched 1000+-client rounds + scenario matrix.

Two measurements in one harness:

1. **Engine benchmark** — one full federated round over N=1024 clients
   (synthetic logreg workload, device-class-mixture capabilities),
   executed twice from identical seeds: once by the batched fleet engine
   (clients vmapped inside a handful of XLA programs) and once by the
   per-client Python loop reference (same jitted math, one client per
   dispatch).  Results must agree (same medoids, params within
   tolerance); the report is clients/sec, virtual round makespan, and the
   batched-over-loop wall-clock speedup (target: ≥ 5×).

2. **Selection-phase breakdown** — the coreset-selection pipeline
   (features → distance stack → k-medoids) over every straggler group
   of the same cohort, fused single-dispatch program (Δ-sweep fast
   path) vs the pre-fusion 3-dispatch chain, plus a Pallas-kernel
   on/off A-B on the fused path.  Records ``selection_wall_s``,
   dispatches-per-group, and the kernel A/B under
   ``BENCH_fleet.json["selection"]``; gates on fused == baseline
   medoids and ``--min-selection-speedup``.

2b. **Selection memory** (``--selection-memory``) — peak selection RSS
   + wall A/B of the distance-free solver vs the materializing
   (C, M, M) stack at M ∈ {128, 512, 2048}, one fresh subprocess per
   point (VmHWM across the cold solve; XLA's allocator retains warm
   buffers, so reused processes can't see the peak).  The stack path is
   skipped at the top M — its O(C·M²) peak is extrapolated from the
   512 point — and the gates are: distance-free completes M = 2048,
   its measured peak there stays under 25% of the extrapolated stack
   peak, and the small-M throughput ratio holds the
   ``--min-selection-memory-speedup`` keep-green.  Results land under
   ``BENCH_fleet.json["selection"]["memory"]``.

3. **Scenario sweep** — every named heterogeneity regime from
   ``repro.fed.fleet.scenarios`` driven through BOTH the synchronous
   server and the async event runtime at smoke scale, so regressions in
   either path show up as a changed loss/makespan row.

3b. **Workload matrix** — every registered ``FleetWorkload`` (mlp, cnn,
   charlm, xlstm, translm) driven through the batched fleet runtime at smoke
   scale with a per-round history recorded under
   ``BENCH_fleet.json["workloads"]``, plus a batched-vs-loop round-0
   parity gate per workload (the rigorous cross-engine matrix lives in
   ``tests/test_workload_conformance.py``).  ``--workload`` additionally
   selects which workload the engine/selection benchmarks (1) and (2)
   run on — the tracked selection gate stays on the default ``mlp``.

3c. **Cost model** (``--cost-model``) — per-workload measured step costs
   (HLO FLOPs per sample, normalized to mlp) under
   ``BENCH_fleet.json["cost_model"]``, plus the deadline A/B on the most
   expensive workload: cost-conditioned budgets vs the κ-ignorant legacy
   sample-count planner on the same device_classes fleet with the same
   measured durations; gates on violation-rate(cost) ≤
   violation-rate(legacy).  The ``make bench-cost`` keep-green target.

4. **Sharded device sweep** (``--device-sweep 1,2,4``) — the mesh-sharded
   engine (``repro.fed.fleet.sharded``) timed at increasing device
   counts on the same fleet, one subprocess per count (XLA fixes the
   host-platform device count at import, so each point re-execs this
   script with ``--xla_force_host_platform_device_count=N``).  Records
   round throughput per device count plus a sharded-vs-batched parity
   check at the largest mesh.  Wall-clock scaling on CPU is bounded by
   the physical core count — the recorded ``n_cpu_cores`` says how much
   parallelism the host could possibly expose.

Writes ``BENCH_fleet.json`` next to this script (override with --out) so
the perf trajectory is tracked in-repo.

  PYTHONPATH=src python benchmarks/fleet_sweep.py --smoke     # CPU, ~2 min
  PYTHONPATH=src python benchmarks/fleet_sweep.py             # full
  PYTHONPATH=src python benchmarks/fleet_sweep.py --smoke \
      --skip-engine --skip-scenarios --device-sweep 1,2,4     # scaling
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import numpy as np

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.fleet.batched import (FleetConfig, FleetEngine,
                                     make_cohort_groups, nominal_budgets,
                                     run_fleet_round)
from repro.fed.fleet.scenarios import SCENARIOS, build_scenario, run_scenario
from repro.fed.fleet.workloads import WORKLOADS, client_sizes, get_workload
from repro.fed.simulator import straggler_deadline
from repro.models.small import LogisticRegression
from repro.utils.xla_env import forced_host_device_env

SWEEP_SCENARIOS = ("uniform", "pareto", "diurnal", "flash_crowd",
                   "device_classes")


def _max_param_diff(a, b) -> float:
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _engine_workload(n_clients: int, epochs: int, batch_size: int,
                     seed: int, use_kernel, workload: str = "mlp"):
    """Shared builder for the engine/selection benchmarks: an n-client
    device-class fleet of the chosen ``FleetWorkload`` (default mlp —
    byte-identical to the pre-workload-axis synthetic-logreg fleet), its
    cohort grouping (timed — the round driver runs it once per round
    either way), and the round-start params."""
    wl = get_workload(workload)
    clients = wl.make_clients(n_clients=n_clients, seed=seed,
                              mean_samples=48.0, std_samples=32.0)
    train, _ = train_test_split_clients(clients, test_frac=0.2)
    specs, _ = build_scenario("device_classes", client_sizes(train), seed)
    model = wl
    cfg = FleetConfig(epochs=epochs, batch_size=batch_size, lr=0.05,
                      seed=seed, use_kernel=use_kernel)
    deadline = straggler_deadline(specs, cfg.epochs, 30.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    params = model.init(jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    groups = make_cohort_groups(train, list(range(len(specs))), budgets,
                                cfg, round_seed=0)
    prep_s = time.perf_counter() - t0
    return model, train, specs, cfg, budgets, params, groups, prep_s


def bench_selection(n_clients: int, epochs: int, batch_size: int,
                    seed: int = 0, use_kernel=None, reps: int = 3,
                    workload: str = "mlp", verbose: bool = False) -> Dict:
    """Selection-phase breakdown: fused single-dispatch program vs the
    pre-fusion 3-dispatch chain, plus a Pallas-kernel on/off A-B.

    "Selection" is the straggler path's feature → distance-stack →
    k-medoids pipeline over every coreset group of one cohort round.  The
    fused path runs it as one jitted program per group (Δ-sweep fast
    path); the unfused baseline replays the dispatch chain this PR
    replaced (jitted feature pass, jitted pairwise program, eager
    diagonal fix-up, jitted legacy-sweep solve).  Warm wall clocks are
    min-over-reps; parity requires identical medoid indices — exact
    equality holds because the fused path's distance-free selection
    materializes below the adaptive ``FleetConfig.materialize_below``
    cutover, which these fleet-sized groups (M < 256) always are (the
    streaming solver is only cost-tied, not bit-identical; its parity
    gate is ``tests/test_distance_free.py``).
    """
    from repro.kernels.ops import resolve_use_kernel
    model, _, _, cfg, _, params, groups, _ = _engine_workload(
        n_clients, epochs, batch_size, seed, use_kernel, workload)
    sgroups = [g for g in groups if g.k > 0]
    if not sgroups:
        raise RuntimeError("selection benchmark found no straggler groups")

    def run(engine, fused):
        outs = [engine.select_group_coresets(params, g, fused=fused)[0]
                for g in sgroups]
        jax.block_until_ready([o.indices for o in outs])
        return outs

    def timed(engine, fused, tag):
        t0 = time.perf_counter()
        outs = run(engine, fused)
        dt = time.perf_counter() - t0
        if verbose:
            print(f"  [{'fused' if fused else 'chain'}] {tag:9s} {dt:8.3f}s")
        return outs, dt

    def measure(engine, fused, tag):
        outs, cold = timed(engine, fused, f"{tag}/cold")
        warm = min(timed(engine, fused, f"{tag}/warm{i}")[1]
                   for i in range(reps))
        return outs, cold, warm

    engine = FleetEngine(model, cfg)
    outs_fused, cold_f, warm_f = measure(engine, True, "auto")
    outs_chain, cold_u, warm_u = measure(engine, False, "legacy")
    meds_equal = all(
        np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
        for a, b in zip(outs_fused, outs_chain))

    # kernel A-B on the fused path (forced on = interpret mode off-TPU)
    ab = {}
    for label, uk in (("on", True), ("off", False)):
        eng = FleetEngine(model, dataclasses.replace(cfg, use_kernel=uk))
        _, _, ab[label] = measure(eng, True, f"kernel-{label}")

    return {
        "workload": workload,
        "n_clients": n_clients,
        "epochs": epochs,
        "n_straggler_groups": len(sgroups),
        "n_coreset_clients": int(sum(g.n_clients for g in sgroups)),
        "budgets_k": sorted({g.k for g in sgroups}),
        "use_kernel_mode": {None: "auto", True: "on",
                            False: "off"}[use_kernel],
        "use_kernel_resolved": resolve_use_kernel(use_kernel),
        "selection_wall_s": warm_f,
        "selection_unfused_wall_s": warm_u,
        "selection_cold_wall_s": cold_f,
        "selection_unfused_cold_wall_s": cold_u,
        "selection_speedup": warm_u / warm_f,
        "dispatches_per_group_fused": 1,
        "dispatches_per_group_unfused": 3,
        "kernel_ab": {"fused_kernel_on_wall_s": ab["on"],
                      "fused_kernel_off_wall_s": ab["off"],
                      # > 1 means forcing the kernels on is slower (on CPU
                      # "on" = interpret mode, which is why auto picks off)
                      "on_over_off_wall_ratio": ab["on"] / ab["off"]},
        "parity_medoids_equal": bool(meds_equal),
    }


SELECTION_MEMORY_MS = (128, 512, 2048)


def _vm_hwm_bytes() -> int:
    """Peak resident set (VmHWM) of this process, in bytes (-1 off-Linux)."""
    try:
        with open("/proc/self/status") as f:
            for ln in f:
                if ln.startswith("VmHWM:"):
                    return int(ln.split()[1]) * 1024
    except OSError:
        pass
    return -1


def selection_memory_worker(variant: str, m: int, c: int, f: int, k: int,
                            reps: int) -> Dict:
    """One (variant, M) selection-memory point, run in a fresh process.

    Peak selection memory is only observable *cold*: XLA's host allocator
    retains warm buffers, so a warm re-solve in a reused process shows a
    zero RSS delta.  Each point therefore re-execs this script and
    measures VmHWM across the first solve (baseline read after the input
    stack is resident, so the delta is the solver's working set plus its
    one-time compile).  ``variant``: ``dfree`` is the shipped default
    (``distance_free=True`` with the adaptive materialize-below-256
    cutover), ``stack`` forces the materializing (C, M, M) baseline.
    Prints a RESULT: JSON line for the parent to parse."""
    import jax.numpy as jnp
    from repro.core.coreset import build_coreset_batched

    rng = np.random.default_rng(1234 + m)
    x = rng.normal(size=(c, m, f)).astype(np.float32)
    valid = np.ones((c, m), bool)
    valid[:, m - max(m // 8, 1):] = False   # engine-style padded tail rows
    x[~valid] = 0.0
    feats = jnp.asarray(x)
    vj = jnp.asarray(valid)
    jax.block_until_ready(feats)
    distance_free = variant == "dfree"

    def solve():
        res = build_coreset_batched(feats, vj, k,
                                    distance_free=distance_free,
                                    max_sweeps=4)
        jax.block_until_ready(res.indices)
        return res

    base = _vm_hwm_bytes()
    t0 = time.perf_counter()
    solve()
    cold = time.perf_counter() - t0
    peak = _vm_hwm_bytes()
    warm = None
    for _ in range(reps):
        t0 = time.perf_counter()
        solve()
        dt = time.perf_counter() - t0
        warm = dt if warm is None else min(warm, dt)
    result = {
        "variant": variant, "m": m, "c": c, "f": f, "k": k,
        "completed": True,
        "cold_wall_s": cold,
        "warm_wall_s": warm,
        "baseline_rss_bytes": base,
        "peak_rss_delta_bytes": max(peak - base, 0),
    }
    print("RESULT:" + json.dumps(result))
    return result


def bench_selection_memory(c: int = 16, f: int = 32, k: int = 16,
                           reps: int = 3, ms=SELECTION_MEMORY_MS) -> Dict:
    """Peak selection memory + large-M throughput A/B (distance-free vs
    materializing stack), one fresh subprocess per point.

    The materializing path is measured up to M = 512 and *skipped* at the
    top M — its (C, M, M) working set extrapolates as O(C·M²) from the
    measured 512 point (16x at 2048), which is exactly the wall the
    distance-free path removes; running it would need ~1 GB at the
    default C = 16 and OOM on smaller CI boxes.  Gates (applied by
    ``main``): the distance-free path must *complete* the top M; its
    measured peak there must stay under 25% of the stack path's
    extrapolated peak; and at the smallest M (below the adaptive
    materialize cutover, where both variants run the same program) the
    distance-free warm wall must hold the keep-green ≥1x throughput
    ratio."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    m_max = max(ms)
    points: List[Dict] = []
    for m in sorted(ms):
        for variant in ("stack", "dfree"):
            if variant == "stack" and m >= m_max and len(ms) > 1:
                points.append({
                    "variant": variant, "m": m, "c": c, "f": f, "k": k,
                    "completed": False, "skipped": True,
                    "skip_reason": "O(C*M^2) stack at the top M is the "
                                   "wall being measured; peak is "
                                   "extrapolated from the 512 point",
                })
                print(f"  [stack ] M={m:5d}: skipped (extrapolated)")
                continue
            cmd = [sys.executable, os.path.abspath(__file__),
                   "--selection-memory-worker", "--sm-variant", variant,
                   "--sm-m", str(m), "--sm-clients", str(c),
                   "--sm-f", str(f), "--sm-k", str(k),
                   "--sm-reps", str(reps)]
            proc = subprocess.run(cmd, env=forced_host_device_env(1, repo),
                                  capture_output=True, text=True)
            line = next((ln for ln in proc.stdout.splitlines()
                         if ln.startswith("RESULT:")), None)
            if proc.returncode != 0 or line is None:
                row = {"variant": variant, "m": m, "c": c, "f": f, "k": k,
                       "completed": False, "skipped": False,
                       "error": (proc.stderr or proc.stdout)[-2000:]}
                print(f"  [{variant:6s}] M={m:5d}: FAILED")
            else:
                row = json.loads(line[len("RESULT:"):])
                row["skipped"] = False
                print(f"  [{variant:6s}] M={m:5d}: peak "
                      f"{row['peak_rss_delta_bytes'] / 1e6:8.1f} MB  "
                      f"cold {row['cold_wall_s']:.2f}s  "
                      f"warm {row['warm_wall_s']:.3f}s")
            points.append(row)

    def pick(variant, m):
        return next((p for p in points
                     if p["variant"] == variant and p["m"] == m), None)

    m_small, m_mid = min(ms), sorted(ms)[-2] if len(ms) > 1 else min(ms)
    stack_mid = pick("stack", m_mid)
    dfree_top = pick("dfree", m_max)
    d128, s128 = pick("dfree", m_small), pick("stack", m_small)
    extrapolated = None
    peak_ratio = None
    if stack_mid and stack_mid.get("completed"):
        extrapolated = (stack_mid["peak_rss_delta_bytes"]
                        * (m_max / m_mid) ** 2)
        if dfree_top and dfree_top.get("completed"):
            peak_ratio = dfree_top["peak_rss_delta_bytes"] / extrapolated
    speedup_small = None
    if (d128 and s128 and d128.get("completed") and s128.get("completed")):
        speedup_small = s128["warm_wall_s"] / d128["warm_wall_s"]
    return {
        "points": points,
        "m_values": sorted(ms),
        "dfree_completed_top_m": bool(dfree_top
                                      and dfree_top.get("completed")),
        "top_m": m_max,
        "stack_peak_extrapolated_bytes": extrapolated,
        "dfree_top_peak_bytes": (dfree_top or {}).get(
            "peak_rss_delta_bytes"),
        "peak_ratio_vs_extrapolated_stack": peak_ratio,
        "small_m": m_small,
        "small_m_dfree_speedup": speedup_small,
    }


def bench_engine(n_clients: int, epochs: int, batch_size: int,
                 seed: int = 0, use_kernel=None, workload: str = "mlp",
                 verbose: bool = False) -> Dict:
    """Time one identical 1024-client round through both engines."""
    # identical workload to bench_selection (one shared builder), with the
    # cohort grouping prep reported separately; what's timed here is
    # *engine execution*: every group through run_group + aggregate,
    # exactly what run_fleet_round executes
    model, train, specs, cfg, budgets, params, groups, prep_s = \
        _engine_workload(n_clients, epochs, batch_size, seed, use_kernel,
                         workload)
    engine = FleetEngine(model, cfg)
    cids = list(range(len(specs)))

    def timed(batched: bool, tag: str):
        t0 = time.perf_counter()
        out = run_fleet_round(engine, params, train, cids, budgets,
                              round_seed=0, batched=batched,
                              groups=groups)
        jax.block_until_ready(out[0])
        dt = time.perf_counter() - t0
        if verbose:
            label = "batched" if batched else "loop"
            print(f"  [{label}] {tag:6s} {dt:8.3f}s")
        return out, dt

    # cold passes compile every group program; the comparison is the min
    # over warm reps (wall clocks on shared CI boxes are noisy)
    reps = 3
    (_, _), cold_b = timed(True, "cold")
    warm = [timed(True, f"warm{i}") for i in range(reps)]
    (pb, sb), warm_b = warm[0][0], min(dt for _, dt in warm)

    # telemetry overhead: the same warm batched round with the full
    # observability stack live (spans + metrics + JSONL sink), vs the
    # recording-off wall just measured; the <3% budget is what keeps the
    # recorder always-on-able in production runs
    import tempfile
    from repro.obs import JSONLSink, Recorder, use_recorder
    with tempfile.TemporaryDirectory() as tmp:
        rec = Recorder(sinks=[JSONLSink(os.path.join(tmp, "bench.jsonl"))])
        with use_recorder(rec):
            warm_r = min(timed(True, f"rec-warm{i}")[1]
                         for i in range(reps))
        rec.close()
    rec_overhead_pct = 100.0 * (warm_r - warm_b) / warm_b

    (_, _), cold_l = timed(False, "cold")
    warm = [timed(False, f"warm{i}") for i in range(reps)]
    (pl, sl), warm_l = warm[0][0], min(dt for _, dt in warm)

    diff = _max_param_diff(pb, pl)
    meds_equal = (set(sb.medoids) == set(sl.medoids) and all(
        np.array_equal(sb.medoids[c], sl.medoids[c]) for c in sb.medoids))
    speedup = warm_l / warm_b
    makespan = max(sb.work[i] / specs[c].c
                   for i, c in enumerate(sb.cids))
    return {
        "workload": workload,
        "n_clients": n_clients,
        "epochs": epochs,
        "batch_size": batch_size,
        "n_coreset_clients": int(sb.used_coreset.sum()),
        "group_construction_s": prep_s,
        "n_groups": len(groups),
        "batched_wall_s": warm_b,
        "loop_wall_s": warm_l,
        "batched_cold_wall_s": cold_b,
        "loop_cold_wall_s": cold_l,
        "recording_warm_wall_s": warm_r,
        "recording_overhead_pct": rec_overhead_pct,
        "speedup": speedup,
        "clients_per_sec": n_clients / warm_b,
        "round_makespan_virtual_s": float(makespan),
        "parity_max_param_diff": diff,
        "parity_medoids_equal": bool(meds_equal),
    }


class _LazyFleetClients:
    """Sequence view that synthesizes a client's dataset on first access.

    The 100k-client scale point needs 100k ``ClientSpec`` rows but only
    ever trains the few hundred clients the event loop actually
    dispatches — so data is generated per-cid on ``__getitem__`` (mlp
    schema: flat float32 features + int32 labels) and cached.  Sizes are
    fixed up front so specs and data always agree."""

    def __init__(self, sizes: List[int], n_features: int = 60,
                 n_classes: int = 10, seed: int = 0):
        self.sizes = list(sizes)
        self.n_features = n_features
        self.n_classes = n_classes
        self.seed = seed
        self._cache: Dict[int, Dict[str, np.ndarray]] = {}

    def __len__(self) -> int:
        return len(self.sizes)

    @property
    def n_materialized(self) -> int:
        return len(self._cache)

    def __getitem__(self, cid: int) -> Dict[str, np.ndarray]:
        got = self._cache.get(cid)
        if got is None:
            m = self.sizes[cid]
            rng = np.random.default_rng(
                np.random.SeedSequence((self.seed, int(cid))))
            got = {
                "x": rng.normal(
                    size=(m, self.n_features)).astype(np.float32),
                "y": rng.integers(
                    0, self.n_classes, size=m).astype(np.int32),
            }
            self._cache[cid] = got
        return got


def bench_async_fleet(n_clients: int, epochs: int, batch_size: int,
                      seed: int = 0, use_kernel=None, workload: str = "mlp",
                      flushes: int = 4, reps: int = 2,
                      verbose: bool = False) -> Dict:
    """Throughput of the event-driven async fleet engine at the sync
    engine's reference fleet size.

    The same device-class fleet as ``bench_engine``, driven through
    ``run_async_fleet`` with the whole fleet in flight and K sized so
    ``flushes`` buffer flushes merge every client once — the async
    analogue of one barrier round.  Reported clients/sec is merged
    clients over the min warm wall (a caller-held engine keeps the group
    program cache warm across reps, exactly like the sync benchmark's
    reused engine)."""
    from repro.fed.fleet.async_engine import (AsyncFleetConfig,
                                              run_async_fleet)
    wl = get_workload(workload)
    clients = wl.make_clients(n_clients=n_clients, seed=seed,
                              mean_samples=48.0, std_samples=32.0)
    train, _ = train_test_split_clients(clients, test_frac=0.2)
    specs, trace = build_scenario("device_classes", client_sizes(train),
                                  seed)
    buffer_k = max(1, len(specs) // flushes)
    cfg = AsyncFleetConfig(max_updates=flushes, buffer_k=buffer_k,
                           concurrency=len(specs), epochs=epochs,
                           batch_size=batch_size, lr=0.05, seed=seed,
                           use_kernel=use_kernel, trace=trace)
    eng = FleetEngine(wl, cfg.fleet_config())

    def timed(tag):
        t0 = time.perf_counter()
        out = run_async_fleet(wl, train, specs, cfg, engine="batched",
                              engine_obj=eng)
        jax.block_until_ready(out["params"])
        dt = time.perf_counter() - t0
        if verbose:
            print(f"  [async_fleet] {tag:6s} {dt:8.3f}s")
        return out, dt

    out, cold = timed("cold")
    warm_runs = [timed(f"warm{i}") for i in range(reps)]
    out, warm = warm_runs[0][0], min(dt for _, dt in warm_runs)
    tel = out["telemetry"]
    return {
        "workload": workload,
        "n_clients": len(specs),
        "epochs": epochs,
        "batch_size": batch_size,
        "flushes": int(out["applied"]),
        "buffer_k": buffer_k,
        "cold_wall_s": cold,
        "warm_wall_s": warm,
        "clients_per_sec": tel["n_merged_clients"] / warm,
        "n_merged_clients": tel["n_merged_clients"],
        "n_dispatches": tel["n_dispatches"],
        "n_group_dispatches": tel["n_group_dispatches"],
        "n_partial_flushes": tel["n_partial_flushes"],
        "makespan_virtual_s": tel["makespan"],
        "mean_staleness": tel["mean_staleness"],
        "staleness_hist": np.asarray(tel["staleness_hist"]).tolist(),
        "buffer_occupancy_hist":
            np.asarray(tel["buffer_occupancy_hist"]).tolist(),
        "mean_buffer_occupancy": tel["mean_buffer_occupancy"],
    }


def bench_async_fleet_scale(n_clients: int = 100_000, seed: int = 0,
                            concurrency: int = 256, buffer_k: int = 64,
                            flushes: int = 2, verbose: bool = False) -> Dict:
    """The 100k-client completion point: a fleet of 100k specs through
    the event-driven engine on CPU.  Feasible because (a) dispatch waves
    and the event queue are O(in-flight), not O(fleet), (b) jitted
    dispatches scale with cohort-group shapes per flush, not clients,
    and (c) client data is materialized lazily — only dispatched cids
    ever exist in memory."""
    from repro.fed.fleet.async_engine import (AsyncFleetConfig,
                                              run_async_fleet)
    rng = np.random.default_rng(seed)
    sizes = np.clip(rng.normal(48.0, 32.0, n_clients), 8, None).astype(int)
    specs, trace = build_scenario("device_classes", sizes.tolist(), seed)
    train = _LazyFleetClients(sizes.tolist(), seed=seed)
    cfg = AsyncFleetConfig(max_updates=flushes, buffer_k=buffer_k,
                           concurrency=concurrency, epochs=2, batch_size=8,
                           lr=0.05, seed=seed, trace=trace)
    t0 = time.perf_counter()
    out = run_async_fleet(get_workload("mlp"), train, specs, cfg,
                          engine="batched")
    jax.block_until_ready(out["params"])
    wall = time.perf_counter() - t0
    tel = out["telemetry"]
    row = {
        "n_clients": n_clients,
        "concurrency": concurrency,
        "buffer_k": buffer_k,
        "flushes": int(out["applied"]),
        "wall_s": wall,
        "n_dispatches": tel["n_dispatches"],
        "n_group_dispatches": tel["n_group_dispatches"],
        "n_merged_clients": tel["n_merged_clients"],
        "n_clients_materialized": train.n_materialized,
        "makespan_virtual_s": tel["makespan"],
        "completed": bool(out["applied"] >= 1),
    }
    if verbose:
        print(f"  scale point ({n_clients} clients): {wall:.1f}s wall, "
              f"{row['n_dispatches']} client dispatches -> "
              f"{row['n_group_dispatches']} group programs, "
              f"{row['n_clients_materialized']} of {n_clients} clients "
              f"materialized")
    return row


def _sharded_fleet(n_clients: int, epochs: int, batch_size: int, seed: int):
    """Shared workload builder for the device sweep (worker + parity)."""
    clients = synthetic_dataset(0.5, 0.5, n_clients=n_clients,
                                mean_samples=160.0, std_samples=64.0,
                                seed=seed)
    train, _ = train_test_split_clients(clients, test_frac=0.2)
    sizes = [len(d["y"]) for d in train]
    specs, _ = build_scenario("device_classes", sizes, seed)
    model = LogisticRegression()
    cfg = FleetConfig(epochs=epochs, batch_size=batch_size, lr=0.05,
                      seed=seed)
    deadline = straggler_deadline(specs, cfg.epochs, 30.0)
    budgets = nominal_budgets(specs, deadline, cfg.epochs)
    return model, train, specs, cfg, budgets


def sharded_worker(n_clients: int, epochs: int, batch_size: int, seed: int,
                   parity: bool, reps: int = 5) -> Dict:
    """One device-sweep point: time sharded rounds at this process's
    device count.  Prints a RESULT: JSON line for the parent to parse."""
    from repro.fed.fleet.sharded import ShardedFleetEngine, client_mesh
    model, train, specs, cfg, budgets = _sharded_fleet(
        n_clients, epochs, batch_size, seed)
    params = model.init(jax.random.PRNGKey(seed))
    cids = list(range(len(specs)))
    groups = make_cohort_groups(train, cids, budgets, cfg, round_seed=0)
    engine = ShardedFleetEngine(model, cfg, mesh=client_mesh())

    def timed(eng, mode):
        t0 = time.perf_counter()
        out = run_fleet_round(eng, params, train, cids, budgets,
                              round_seed=0, mode=mode, groups=groups)
        jax.block_until_ready(out[0])
        return out, time.perf_counter() - t0

    (_, _), cold = timed(engine, "sharded")
    warm_runs = [timed(engine, "sharded") for _ in range(reps)]
    (ps, ss), warm = warm_runs[0][0], min(dt for _, dt in warm_runs)
    result = {
        "n_devices": len(jax.devices()),
        "n_clients": n_clients,
        "cold_wall_s": cold,
        "warm_wall_s": warm,
        "clients_per_sec": n_clients / warm,
    }
    if parity:
        eng_b = FleetEngine(model, cfg)
        timed(eng_b, "batched")     # compile
        (pb, sb), _ = timed(eng_b, "batched")
        result["parity_max_param_diff"] = _max_param_diff(ps, pb)
        result["parity_medoids_equal"] = bool(
            set(ss.medoids) == set(sb.medoids) and all(
                np.array_equal(ss.medoids[c], sb.medoids[c])
                for c in sb.medoids))
    print("RESULT:" + json.dumps(result))
    return result


def bench_sharded_scaling(device_counts: List[int], n_clients: int,
                          epochs: int, batch_size: int, seed: int) -> Dict:
    """Run one subprocess per device count; collect throughput + parity."""
    per_count: Dict[str, Dict] = {}
    for nd in device_counts:
        cmd = [sys.executable, os.path.abspath(__file__), "--sharded-worker",
               "--clients", str(n_clients), "--epochs", str(epochs),
               "--batch-size", str(batch_size), "--seed", str(seed)]
        if nd == max(device_counts):
            cmd.append("--parity")
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        proc = subprocess.run(cmd, env=forced_host_device_env(nd, repo),
                              capture_output=True, text=True)
        line = next((ln for ln in proc.stdout.splitlines()
                     if ln.startswith("RESULT:")), None)
        if proc.returncode != 0 or line is None:
            raise RuntimeError(
                f"device-sweep worker (devices={nd}) failed:\n"
                f"{proc.stdout}\n{proc.stderr}")
        row = json.loads(line[len("RESULT:"):])
        per_count[str(nd)] = row
        print(f"  devices {nd}: warm {row['warm_wall_s']:.3f}s "
              f"({row['clients_per_sec']:.0f} clients/s)")
    lo, hi = str(min(device_counts)), str(max(device_counts))
    speedup = (per_count[hi]["clients_per_sec"]
               / per_count[lo]["clients_per_sec"])
    return {
        "n_cpu_cores": os.cpu_count(),
        "device_counts": device_counts,
        "workload": {"n_clients": n_clients, "epochs": epochs,
                     "batch_size": batch_size, "seed": seed},
        "per_device_count": per_count,
        "throughput_speedup_max_vs_min": speedup,
        "parity_max_param_diff":
            per_count[hi].get("parity_max_param_diff"),
        "parity_medoids_equal": per_count[hi].get("parity_medoids_equal"),
    }


def sweep_workloads(names, rounds: int, epochs: int, n_clients: int = 24,
                    seed: int = 0, verbose: bool = False) -> Dict:
    """Per-workload fleet rounds: every registered ``FleetWorkload``
    through the batched fleet runtime via the scenario registry, with a
    per-round history row and a batched-vs-loop round-0 parity gate
    (identical train loss / test acc to float32 tolerance)."""
    table = {}
    for name in names:
        wl = get_workload(name)
        clients = wl.make_clients(n_clients=n_clients, seed=seed)
        train, test = train_test_split_clients(clients, test_frac=0.2)
        t0 = time.perf_counter()
        out = run_scenario("device_classes", "fleet", clients_data=train,
                           test_data=test, workload=wl, seed=seed,
                           rounds=rounds, epochs=epochs, batch_size=8,
                           fleet_engine="batched")
        wall = time.perf_counter() - t0
        ref = run_scenario("device_classes", "fleet", clients_data=train,
                           test_data=test, workload=wl, seed=seed,
                           rounds=1, epochs=epochs, batch_size=8,
                           fleet_engine="loop")
        h0, r0 = out["history"][0], ref["history"][0]
        parity = (abs(h0.train_loss - r0.train_loss) < 1e-4
                  and abs(h0.test_acc - r0.test_acc) < 1e-4)
        hist = out["history"]
        table[name] = {
            "description": wl.description,
            "n_clients": len(train),
            "batched_wall_s": wall,
            "final_train_loss": float(hist[-1].train_loss),
            "final_test_acc": float(hist[-1].test_acc),
            "n_coreset_total": int(sum(r.n_coreset for r in hist)),
            "parity_loop_round0": bool(parity),
            "rounds": [{
                "round": r.round,
                "train_loss": float(r.train_loss),
                "test_acc": float(r.test_acc),
                "sim_round_time": float(r.sim_round_time),
                "n_coreset": int(r.n_coreset),
            } for r in hist],
        }
        if verbose:
            print(f"  {name:8s} loss={table[name]['final_train_loss']:.3f} "
                  f"acc={table[name]['final_test_acc']:.3f} "
                  f"core={table[name]['n_coreset_total']:3d} "
                  f"wall={wall:6.2f}s "
                  f"parity={'PASS' if parity else 'FAIL'}")
    return table


class _LegacySamplePlanner:
    """κ-ignorant baseline planner: §4.2 budgets that treat the deadline
    as a *sample count* (the pre-cost-model arithmetic), with full
    participation and no adaptation.  Implemented as a scheduler-protocol
    stub so ``run_fleet`` still prices realized durations through the
    true measured cost model while the *budgets* ignore it — the
    controlled A/B the cost-model gate runs."""

    def __init__(self, specs):
        self.specs = specs

    def select(self):
        return np.arange(len(self.specs))

    def budget(self, cid: int, deadline: float, epochs: int) -> int:
        from repro.fed.cost import UNIT_COST
        s = self.specs[cid]
        if not UNIT_COST.needs_coreset(s.m, s.c, deadline, epochs):
            return s.m
        return UNIT_COST.budget(s.m, s.c, deadline, epochs)

    def observe(self, cid, work_units, duration):
        pass

    def record_round(self, train_loss):
        pass


def _violation_rate(out) -> float:
    n_v = sum(r.n_violations for r in out["history"])
    n_p = sum(r.n_participants for r in out["history"])
    return n_v / max(n_p, 1)


def bench_cost_model(gate_workload: str = "translm", n_clients: int = 24,
                     rounds: int = 3, epochs: int = 2, batch_size: int = 8,
                     seed: int = 0, verbose: bool = False) -> Dict:
    """Cost-conditioned budgets: the measured table + the deadline A/B.

    Part 1 measures every registered workload's per-sample step cost
    (HLO FLOPs of the jitted local-SGD step, wall-clock fallback),
    normalized to the mlp reference — the table budget conditioning
    consumes.

    Part 2 is the divergence experiment on ``gate_workload`` under the
    ``device_classes`` mixture: the same fleet, trace, and *measured*
    per-sample durations twice — once with cost-conditioned budgets
    (``FleetConfig.cost``), once with the κ-ignorant legacy sample-count
    planner.  On an expensive workload the legacy planner reads the
    cost-calibrated deadline as ~κ× more samples than truly fit and
    overcommits; the recorded deadline-violation rates are the gate
    (cost ≤ legacy)."""
    from repro.fed.cost import workload_cost_model
    from repro.fed.fleet.batched import run_fleet

    flops_table = {}
    for name in sorted(WORKLOADS):
        cm = workload_cost_model(name)
        flops_table[name] = {
            "cost_per_sample_rel": cm.cost_per_sample,
            "flops_per_sample": cm.flops_per_sample,
            "source": cm.source,
        }
        if verbose:
            print(f"  {name:8s} source={cm.source:9s} "
                  f"rel={cm.cost_per_sample:9.2f} "
                  f"flops/sample={cm.flops_per_sample}")

    wl = get_workload(gate_workload)
    clients = wl.make_clients(n_clients=n_clients, seed=seed)
    train, _ = train_test_split_clients(clients, test_frac=0.2)
    specs, trace = build_scenario("device_classes", client_sizes(train),
                                  seed)
    cm = workload_cost_model(gate_workload)
    cfg = FleetConfig(epochs=epochs, batch_size=batch_size, lr=0.05,
                      seed=seed, cost=cm)

    def run(scheduler):
        t0 = time.perf_counter()
        out = run_fleet(wl, train, specs, cfg, rounds=rounds, trace=trace,
                        straggler_pct=30.0, scheduler=scheduler)
        return out, time.perf_counter() - t0

    out_cost, wall_c = run(None)
    out_legacy, wall_l = run(_LegacySamplePlanner(specs))
    rate_cost = _violation_rate(out_cost)
    rate_legacy = _violation_rate(out_legacy)
    if verbose:
        print(f"  {gate_workload} x device_classes "
              f"(κ={cm.cost_per_sample:.1f}): violation rate "
              f"cost={rate_cost:.3f} vs legacy={rate_legacy:.3f}")
    return {
        "reference": "mlp",
        "per_workload": flops_table,
        "gate": {
            "workload": gate_workload,
            "scenario": "device_classes",
            "n_clients": len(specs),
            "rounds": rounds,
            "epochs": epochs,
            "cost_per_sample_rel": cm.cost_per_sample,
            "deadline_violation_rate_cost": rate_cost,
            "deadline_violation_rate_legacy": rate_legacy,
            "n_coreset_cost": int(sum(r.n_coreset
                                      for r in out_cost["history"])),
            "n_coreset_legacy": int(sum(r.n_coreset
                                        for r in out_legacy["history"])),
            "wall_s_cost": wall_c,
            "wall_s_legacy": wall_l,
        },
    }


def bench_faults(n_clients: int = 20, rounds: int = 3,
                 gate_rounds: int = 12, epochs: int = 1,
                 batch_size: int = 8, seed: int = 0,
                 verbose: bool = False) -> Dict:
    """Fault matrix + the Byzantine robustness gate.

    Part 1 crosses fault profiles with server aggregation rules on the
    mlp fleet workload (short horizon — it checks every cell *runs* and
    records its fault accounting, not asymptotics).

    Part 2 is the gate: under 20% sign-flip Byzantine clients, at least
    one robust aggregator's final eval accuracy must exceed
    weighted-mean's.  Sign-flip only *slows* the mean early on — the
    separation appears once honest clients approach their optimum and
    the Byzantine bias becomes the binding constraint — so the gate runs
    a longer horizon than the matrix."""
    from repro.fed.fleet.batched import run_fleet

    wl = get_workload("mlp")
    clients = wl.make_clients(n_clients=n_clients, seed=seed,
                              mean_samples=60.0, std_samples=40.0)
    train, test = train_test_split_clients(clients, test_frac=0.15)
    specs, _ = build_scenario("uniform", client_sizes(train), seed)

    def run(agg, profile, n_rounds):
        cfg = FleetConfig(epochs=epochs, batch_size=batch_size,
                          seed=seed, aggregator=agg)
        out = run_fleet(wl, train, specs, cfg, rounds=n_rounds,
                        test_data=test, faults=profile)
        hist = out["history"]
        return {
            "final_test_acc": float(hist[-1].test_acc),
            "final_test_loss": float(hist[-1].test_loss),
            "accs": [float(r.test_acc) for r in hist],
            "n_dropped": int(sum(r.n_dropped for r in hist)),
            "n_violations": int(sum(r.n_violations for r in hist)),
        }

    profiles = ("none", "dropout", "churn", "byzantine_signflip")
    aggs = ("weighted_mean", "trimmed_mean", "median", "krum")
    matrix = {}
    for profile in profiles:
        row = {}
        for agg in aggs:
            cell = row[agg] = run(agg, profile, rounds)
            if verbose:
                print(f"  {profile:20s} {agg:14s} "
                      f"acc={cell['final_test_acc']:.3f} "
                      f"dropped={cell['n_dropped']}")
        matrix[profile] = row

    gate_aggs = ("weighted_mean", "trimmed_mean", "norm_clip")
    gate = {agg: run(agg, "byzantine_signflip", gate_rounds)
            for agg in gate_aggs}
    mean_acc = gate["weighted_mean"]["final_test_acc"]
    best_robust = max((a for a in gate_aggs if a != "weighted_mean"),
                      key=lambda a: gate[a]["final_test_acc"])
    margin = gate[best_robust]["final_test_acc"] - mean_acc
    if verbose:
        print(f"  gate ({gate_rounds} rounds, byzantine_signflip): "
              f"{best_robust} {gate[best_robust]['final_test_acc']:.3f} "
              f"vs weighted_mean {mean_acc:.3f} (margin {margin:+.3f})")
    return {
        "workload": "mlp",
        "scenario": "uniform",
        "n_clients": len(specs),
        "rounds": rounds,
        "epochs": epochs,
        "matrix": matrix,
        "gate": {
            "profile": "byzantine_signflip",
            "rounds": gate_rounds,
            "cells": gate,
            "best_robust": best_robust,
            "weighted_mean_acc": mean_acc,
            "best_robust_acc": gate[best_robust]["final_test_acc"],
            "robust_margin": margin,
        },
    }


def sweep_scenarios(n_clients: int, rounds: int, epochs: int,
                    seed: int = 0, verbose: bool = False) -> Dict:
    """Every named scenario through both the sync server and the async
    event runtime, from the one registry."""
    clients = synthetic_dataset(0.5, 0.5, n_clients=n_clients,
                                mean_samples=60.0, std_samples=60.0,
                                seed=seed)
    train, test = train_test_split_clients(clients, test_frac=0.3)
    model = LogisticRegression()
    table = {}
    for name in SWEEP_SCENARIOS:
        row = {"description": SCENARIOS[name].description}
        for runtime in ("sync", "async"):
            out = run_scenario(name, runtime, model, train, test,
                               seed=seed, rounds=rounds,
                               clients_per_round=max(4, n_clients // 6),
                               epochs=epochs, batch_size=8,
                               verbose=verbose)
            hist = out["history"]
            accs = [r.test_acc for r in hist if np.isfinite(r.test_acc)]
            makespan = (out["telemetry"]["makespan"] if runtime == "async"
                        else sum(r.sim_round_time for r in hist))
            row[runtime] = {
                "final_train_loss": float(hist[-1].train_loss),
                "final_test_acc": float(accs[-1]) if accs else float("nan"),
                "makespan_virtual_s": float(makespan),
                "n_coreset": int(sum(r.n_coreset for r in hist)),
            }
            if verbose:
                print(f"  {name:15s} {runtime:6s} "
                      f"acc={row[runtime]['final_test_acc']:.3f} "
                      f"makespan={makespan:9.1f}")
        table[name] = row
    return table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-sized run (the CI/Make target)")
    ap.add_argument("--clients", type=int, default=None,
                    help="engine-benchmark fleet size (default 1024)")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="tri-state Pallas switch for the selection fast "
                         "path: auto = kernels on supported backends, jnp "
                         "fallback otherwise (FleetConfig.use_kernel)")
    ap.add_argument("--workload", choices=tuple(sorted(WORKLOADS)),
                    default="mlp",
                    help="FleetWorkload for the engine/selection "
                         "benchmarks (the tracked selection gate runs on "
                         "the default mlp); the workload matrix section "
                         "always sweeps every registered workload")
    ap.add_argument("--skip-workloads", action="store_true",
                    help="skip the per-workload fleet-rounds matrix")
    ap.add_argument("--cost-model", action="store_true",
                    help="measure per-workload step costs (FLOPs/sample) "
                         "and run the cost-vs-legacy deadline-violation "
                         "A/B on --cost-gate-workload under "
                         "device_classes; gates cost rate <= legacy rate")
    ap.add_argument("--cost-gate-workload", default="translm",
                    choices=tuple(sorted(WORKLOADS)),
                    help="workload for the cost-model divergence gate "
                         "(default translm, the most expensive per "
                         "sample)")
    ap.add_argument("--faults", action="store_true",
                    help="run the fault matrix (dropout / churn / "
                         "Byzantine x aggregation rules) and the "
                         "Byzantine robustness gate: under 20% sign-flip "
                         "clients a robust aggregator must beat "
                         "weighted_mean's final accuracy")
    ap.add_argument("--skip-scenarios", action="store_true")
    ap.add_argument("--skip-engine", action="store_true")
    ap.add_argument("--skip-selection", action="store_true",
                    help="skip the selection-phase breakdown benchmark")
    ap.add_argument("--async-fleet", action="store_true",
                    help="benchmark the event-driven async fleet engine: "
                         "throughput at the reference fleet size vs the "
                         "sync batched round, plus the 100k-client lazy "
                         "completion point")
    ap.add_argument("--min-async-ratio", type=float, default=0.5,
                    help="fail if async_fleet clients/sec falls below this "
                         "fraction of the sync batched engine's (needs the "
                         "engine section in this run or the tracked file)")
    ap.add_argument("--async-scale-clients", type=int, default=100_000,
                    help="fleet size for the async_fleet lazy scale point "
                         "(0 disables it)")
    ap.add_argument("--min-speedup", type=float, default=5.0)
    ap.add_argument("--max-recording-overhead", type=float, default=3.0,
                    help="fail if the full observability stack (spans + "
                         "metrics + JSONL sink) slows the warm batched "
                         "round by more than this percentage")
    ap.add_argument("--min-selection-speedup", type=float, default=1.5,
                    help="fail if the fused selection path is not at least "
                         "this much faster than the pre-fusion dispatch "
                         "chain (1.0 = no-regression keep-green gate)")
    ap.add_argument("--device-sweep", default="",
                    help="comma-separated device counts for the sharded "
                         "engine scaling sweep (e.g. 1,2,4); each count "
                         "runs in a subprocess with XLA's forced "
                         "host-platform device count")
    ap.add_argument("--min-scaling", type=float, default=0.0,
                    help="fail if max-vs-min device throughput gain falls "
                         "below this (0 = record only; CPU wall-clock "
                         "scaling is bounded by physical cores)")
    ap.add_argument("--selection-memory", action="store_true",
                    help="peak selection memory + large-M throughput A/B: "
                         "distance-free vs materializing (C, M, M) stack "
                         "at M in {128, 512, 2048}, one fresh subprocess "
                         "per point (VmHWM across the cold solve); "
                         "results land in BENCH_fleet.json['selection']"
                         "['memory']")
    ap.add_argument("--min-selection-memory-speedup", type=float,
                    default=1.0,
                    help="fail if the distance-free warm wall at the "
                         "smallest memory-sweep M falls below this ratio "
                         "of the stack path's (1.0 = keep-green; below "
                         "the adaptive cutover both variants run the "
                         "same program, so this guards the cutover "
                         "default; 5%% timer-jitter tolerance applied)")
    ap.add_argument("--sharded-worker", action="store_true",
                    help=argparse.SUPPRESS)   # internal: one sweep point
    ap.add_argument("--parity", action="store_true",
                    help=argparse.SUPPRESS)   # worker: also check parity
    ap.add_argument("--selection-memory-worker", action="store_true",
                    help=argparse.SUPPRESS)   # internal: one memory point
    ap.add_argument("--sm-variant", choices=("dfree", "stack"),
                    default="dfree", help=argparse.SUPPRESS)
    ap.add_argument("--sm-m", type=int, default=512,
                    help=argparse.SUPPRESS)
    ap.add_argument("--sm-clients", type=int, default=16,
                    help=argparse.SUPPRESS)
    ap.add_argument("--sm-f", type=int, default=32,
                    help=argparse.SUPPRESS)
    ap.add_argument("--sm-k", type=int, default=16,
                    help=argparse.SUPPRESS)
    ap.add_argument("--sm-reps", type=int, default=3,
                    help=argparse.SUPPRESS)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_fleet.json"))
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.sharded_worker:
        sharded_worker(args.clients or 512, args.epochs or 3,
                       args.batch_size, args.seed, parity=args.parity)
        return 0
    if args.selection_memory_worker:
        selection_memory_worker(args.sm_variant, args.sm_m,
                                args.sm_clients, args.sm_f, args.sm_k,
                                args.sm_reps)
        return 0

    n_clients = args.clients or 1024
    epochs = args.epochs or (2 if args.smoke else 3)
    use_kernel = {"auto": None, "on": True, "off": False}[args.use_kernel]
    report = {"mode": "smoke" if args.smoke else "full",
              "backend": jax.default_backend()}
    ok = True

    if not args.skip_engine:
        print(f"== engine: one {n_clients}-client round "
              f"({args.workload}), batched vs per-client loop")
        eng = bench_engine(n_clients, epochs, args.batch_size,
                           seed=args.seed, use_kernel=use_kernel,
                           workload=args.workload, verbose=True)
        report["engine"] = eng
        print(f"  clients/sec (batched): {eng['clients_per_sec']:10.1f}")
        print(f"  round makespan (virtual): "
              f"{eng['round_makespan_virtual_s']:8.1f}s")
        print(f"  wall: batched {eng['batched_wall_s']:.3f}s  "
              f"loop {eng['loop_wall_s']:.3f}s  "
              f"speedup {eng['speedup']:.1f}x")
        parity = (eng["parity_medoids_equal"]
                  and eng["parity_max_param_diff"] < 1e-4)
        print(f"  [{'PASS' if parity else 'FAIL'}] parity: medoids equal, "
              f"max param diff {eng['parity_max_param_diff']:.2e}")
        fast = eng["speedup"] >= args.min_speedup
        print(f"  [{'PASS' if fast else 'FAIL'}] speedup "
              f"{eng['speedup']:.1f}x >= {args.min_speedup:.1f}x")
        lean = (eng["recording_overhead_pct"]
                <= args.max_recording_overhead)
        print(f"  [{'PASS' if lean else 'FAIL'}] telemetry overhead "
              f"{eng['recording_overhead_pct']:+.2f}% <= "
              f"{args.max_recording_overhead:.1f}% "
              f"(recording {eng['recording_warm_wall_s']:.3f}s vs "
              f"off {eng['batched_wall_s']:.3f}s)")
        ok = ok and parity and fast and lean

    if not args.skip_selection:
        print(f"\n== selection: coreset-selection phase at {n_clients} "
              f"clients, fused single-dispatch vs pre-fusion chain "
              f"(kernels: {args.use_kernel})")
        sel = bench_selection(n_clients, epochs, args.batch_size,
                              seed=args.seed, use_kernel=use_kernel,
                              workload=args.workload,
                              verbose=args.verbose)
        report["selection"] = sel
        print(f"  {sel['n_coreset_clients']} coreset clients in "
              f"{sel['n_straggler_groups']} groups, k in "
              f"{sel['budgets_k']}")
        print(f"  wall: fused {sel['selection_wall_s']:.3f}s "
              f"({sel['dispatches_per_group_fused']} dispatch/group)  "
              f"chain {sel['selection_unfused_wall_s']:.3f}s "
              f"({sel['dispatches_per_group_unfused']} dispatches/group)")
        print(f"  kernel A/B (fused): on "
              f"{sel['kernel_ab']['fused_kernel_on_wall_s']:.3f}s  off "
              f"{sel['kernel_ab']['fused_kernel_off_wall_s']:.3f}s")
        sel_parity = sel["parity_medoids_equal"]
        print(f"  [{'PASS' if sel_parity else 'FAIL'}] parity: fused "
              f"medoids == pre-fusion chain medoids")
        sel_fast = sel["selection_speedup"] >= args.min_selection_speedup
        print(f"  [{'PASS' if sel_fast else 'FAIL'}] selection speedup "
              f"{sel['selection_speedup']:.2f}x >= "
              f"{args.min_selection_speedup:.1f}x")
        ok = ok and sel_parity and sel_fast

    if args.selection_memory:
        print("\n== selection-memory: peak RSS + wall A/B, distance-free "
              "vs materializing (C, M, M) stack (fresh subprocess per "
              "point)")
        mem = bench_selection_memory()
        report.setdefault("selection", {})["memory"] = mem
        completes = mem["dfree_completed_top_m"]
        print(f"  [{'PASS' if completes else 'FAIL'}] distance-free "
              f"completes M={mem['top_m']} (stack path skipped there)")
        ratio = mem["peak_ratio_vs_extrapolated_stack"]
        under = ratio is not None and ratio < 0.25
        if ratio is not None:
            print(f"  [{'PASS' if under else 'FAIL'}] peak at "
                  f"M={mem['top_m']}: "
                  f"{mem['dfree_top_peak_bytes'] / 1e6:.1f} MB = "
                  f"{100.0 * ratio:.1f}% of the stack path's "
                  f"extrapolated "
                  f"{mem['stack_peak_extrapolated_bytes'] / 1e6:.1f} MB "
                  f"(< 25%)")
        else:
            print("  [FAIL] stack baseline point missing — no "
                  "extrapolation")
        sp = mem["small_m_dfree_speedup"]
        floor = args.min_selection_memory_speedup - 0.05
        keep_green = sp is not None and sp >= floor
        print(f"  [{'PASS' if keep_green else 'FAIL'}] M="
              f"{mem['small_m']} throughput: distance-free "
              f"{sp if sp is not None else float('nan'):.2f}x the stack "
              f"path >= {args.min_selection_memory_speedup:.1f}x "
              f"keep-green (5% jitter tolerance)")
        ok = ok and completes and under and keep_green

    if args.async_fleet:
        print(f"\n== async_fleet: event-driven engine at {n_clients} "
              f"clients (micro-batched flushes vs sync batched round)")
        af = bench_async_fleet(n_clients, epochs, args.batch_size,
                               seed=args.seed, use_kernel=use_kernel,
                               workload=args.workload, verbose=True)
        # reference sync throughput: this run's engine section, else the
        # tracked file's (a --skip-engine keep-green run)
        sync_cps = None
        if "engine" in report:
            sync_cps = report["engine"]["clients_per_sec"]
        elif os.path.exists(args.out):
            try:
                with open(args.out) as f:
                    sync_cps = json.load(f)["engine"]["clients_per_sec"]
            except (OSError, json.JSONDecodeError, KeyError):
                sync_cps = None
        af["sync_clients_per_sec_ref"] = sync_cps
        af["async_over_sync_ratio"] = (
            af["clients_per_sec"] / sync_cps if sync_cps else None)
        print(f"  merged {af['n_merged_clients']} clients in "
              f"{af['flushes']} flushes (K={af['buffer_k']}): "
              f"{af['clients_per_sec']:10.1f} clients/sec")
        print(f"  {af['n_dispatches']} client completions -> "
              f"{af['n_group_dispatches']} jitted group dispatches; "
              f"mean staleness {af['mean_staleness']:.2f}, "
              f"mean buffer occupancy {af['mean_buffer_occupancy']:.1f}")
        report["async_fleet"] = af
        if af["async_over_sync_ratio"] is not None:
            near = af["async_over_sync_ratio"] >= args.min_async_ratio
            print(f"  [{'PASS' if near else 'FAIL'}] async/sync throughput "
                  f"{af['async_over_sync_ratio']:.2f}x >= "
                  f"{args.min_async_ratio:.2f}x "
                  f"(sync ref {sync_cps:.1f} clients/sec)")
            ok = ok and near
        else:
            print("  [SKIP] no sync engine reference available for the "
                  "throughput ratio gate")
        if args.async_scale_clients > 0:
            print(f"  scale: {args.async_scale_clients}-client fleet, "
                  f"lazy data, dispatches ~ groups not clients")
            scale = bench_async_fleet_scale(
                args.async_scale_clients, seed=args.seed, verbose=True)
            af["scale"] = scale
            grouped = (scale["n_group_dispatches"]
                       < scale["n_dispatches"])
            done = scale["completed"]
            print(f"  [{'PASS' if done and grouped else 'FAIL'}] "
                  f"{scale['n_clients']}-client sim completed with "
                  f"{scale['n_group_dispatches']} group programs for "
                  f"{scale['n_dispatches']} client dispatches")
            ok = ok and done and grouped

    if not args.skip_workloads:
        wl_rounds = 2 if args.smoke else 4
        names = tuple(sorted(WORKLOADS))
        print(f"\n== workloads: {len(names)} FleetWorkloads x fleet "
              f"runtime ({wl_rounds} rounds, batched + loop parity)")
        report["workloads"] = sweep_workloads(
            names, wl_rounds, epochs=2 if args.smoke else 3,
            seed=args.seed, verbose=True)
        wl_parity = all(row["parity_loop_round0"]
                        for row in report["workloads"].values())
        print(f"  [{'PASS' if wl_parity else 'FAIL'}] batched==loop "
              f"round-0 parity on every workload")
        ok = ok and wl_parity

    if args.cost_model:
        print(f"\n== cost model: measured per-sample step costs + "
              f"deadline-violation A/B ({args.cost_gate_workload} x "
              f"device_classes)")
        cmrep = bench_cost_model(
            gate_workload=args.cost_gate_workload,
            n_clients=24 if args.smoke else 64,
            rounds=3 if args.smoke else 6,
            epochs=2 if args.smoke else 3,
            seed=args.seed, verbose=True)
        report["cost_model"] = cmrep
        g = cmrep["gate"]
        better = (g["deadline_violation_rate_cost"]
                  <= g["deadline_violation_rate_legacy"] + 1e-12)
        print(f"  [{'PASS' if better else 'FAIL'}] cost-conditioned "
              f"violation rate {g['deadline_violation_rate_cost']:.3f} <= "
              f"legacy sample-count rate "
              f"{g['deadline_violation_rate_legacy']:.3f}")
        ok = ok and better

    if args.faults:
        print("\n== faults: dropout / churn / Byzantine x aggregation "
              "rules, plus the sign-flip robustness gate")
        frep = bench_faults(n_clients=20 if args.smoke else 48,
                            rounds=3 if args.smoke else 6,
                            gate_rounds=12 if args.smoke else 20,
                            epochs=1, batch_size=8, seed=args.seed,
                            verbose=True)
        report["faults"] = frep
        g = frep["gate"]
        robust = g["robust_margin"] > 0.0
        print(f"  [{'PASS' if robust else 'FAIL'}] {g['best_robust']} beats "
              f"weighted_mean under {g['profile']}: "
              f"{g['best_robust_acc']:.3f} vs {g['weighted_mean_acc']:.3f} "
              f"(margin {g['robust_margin']:+.3f})")
        ok = ok and robust

    if not args.skip_scenarios:
        sc_clients = 24 if args.smoke else 64
        sc_rounds = 3 if args.smoke else 8
        print(f"\n== scenarios: {len(SWEEP_SCENARIOS)} regimes x "
              f"{{sync, async}} at {sc_clients} clients")
        report["scenarios"] = sweep_scenarios(
            sc_clients, sc_rounds, epochs=2 if args.smoke else 3,
            seed=args.seed, verbose=True)

    if args.device_sweep:
        counts = sorted({int(c) for c in args.device_sweep.split(",")})
        sw_clients = args.clients or (512 if args.smoke else 1024)
        print(f"\n== sharded engine: device sweep {counts} at "
              f"{sw_clients} clients ({os.cpu_count()} physical cores)")
        scaling = bench_sharded_scaling(counts, sw_clients,
                                        args.epochs or 3, args.batch_size,
                                        args.seed)
        report["sharded_scaling"] = scaling
        gain = scaling["throughput_speedup_max_vs_min"]
        parity_ok = (scaling["parity_medoids_equal"] is not False and
                     (scaling["parity_max_param_diff"] or 0.0) < 1e-4)
        print(f"  [{'PASS' if parity_ok else 'FAIL'}] sharded==batched "
              f"parity at {max(counts)} devices "
              f"(max param diff {scaling['parity_max_param_diff']:.2e})")
        print(f"  throughput gain {max(counts)}dev vs {min(counts)}dev: "
              f"{gain:.2f}x (host has {os.cpu_count()} cores)")
        ok = ok and parity_ok
        if args.min_scaling > 0:
            scaled = gain >= args.min_scaling
            print(f"  [{'PASS' if scaled else 'FAIL'}] scaling {gain:.2f}x "
                  f">= {args.min_scaling:.1f}x")
            ok = ok and scaled

    # partial runs (--skip-*) update their sections of the tracked report
    # instead of clobbering the others
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
        if args.skip_engine and "mode" in merged:
            # a sections-only run must not relabel the mode that produced
            # the headline engine numbers already in the file
            report.pop("mode", None)
        merged.update(report)
        report = merged
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"\nwrote {args.out}")
    print(f"overall: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
