"""Theorem 5.1 validation: E[L(w_R)] - L* <= O(eps) + O(1/R).

On the strongly-convex Synthetic LR benchmark (where the theorem's
assumptions hold) with the Thm-A.7 learning rate eta_t = alpha/(t+beta):
run FedCore for increasing round budgets R and fit

    suboptimality(R) ~= A + B / R

A least-squares fit with A (the eps-floor) and B (the federated
optimization constant) should explain the curve (R^2 high), A should be
small and positive (coreset bias floor), and the trend must be decreasing
in R — the paper's trade-off made measurable.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.data.partition import train_test_split_clients
from repro.data.synthetic import synthetic_dataset
from repro.fed.server import FLConfig, run_federated
from repro.fed.strategies import FedCore, LocalTrainer
from repro.models.small import LogisticRegression
from repro.models.training import make_train_step
from repro.optim.optimizers import sgd


def global_loss(model, params, clients):
    import jax.numpy as jnp
    total, n = 0.0, 0
    for d in clients:
        batch = {k: jnp.asarray(v) for k, v in d.items()}
        loss, _ = model.loss(params, batch)
        m = len(d["y"])
        total += float(loss) * m
        n += m
    return total / n


def near_optimal_loss(model, clients, steps=3000, lr=0.5):
    """Centralized full-gradient descent to approximate L*."""
    import jax.numpy as jnp
    data = {k: jnp.asarray(np.concatenate([c[k] for c in clients]))
            for k in clients[0]}
    params = model.init(jax.random.PRNGKey(0))
    opt = sgd(lr)
    step = make_train_step(model.loss, opt, donate=False)
    st = opt.init(params)
    for _ in range(steps):
        params, st, metrics = step(params, st, data)
    return float(metrics["loss"])


def run(rounds_grid=(4, 8, 16, 32), seed: int = 0):
    clients = synthetic_dataset(0.5, 0.5, n_clients=10, mean_samples=80,
                                std_samples=40, seed=seed)
    train, _ = train_test_split_clients(clients)
    from repro.fed.simulator import make_client_specs
    specs = make_client_specs([len(d["y"]) for d in train],
                              np.random.default_rng(seed))
    model = LogisticRegression()
    l_star = near_optimal_loss(model, train)

    subopt = []
    for R in rounds_grid:
        cfg = FLConfig(rounds=R, clients_per_round=5, epochs=5,
                       batch_size=8, lr=0.05, straggler_pct=30.0,
                       seed=seed, eval_every=10**9)
        trainer = LocalTrainer(model, cfg.lr, cfg.batch_size)
        out = run_federated(model, train, specs, FedCore(trainer), cfg)
        gap = max(global_loss(model, out["params"], train) - l_star, 1e-9)
        subopt.append(gap)

    # fit gap ~= A + B/R
    R = np.asarray(rounds_grid, float)
    y = np.asarray(subopt)
    X = np.stack([np.ones_like(R), 1.0 / R], 1)
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    pred = X @ coef
    ss_res = np.sum((y - pred) ** 2)
    ss_tot = np.sum((y - y.mean()) ** 2) + 1e-12
    return {
        "rounds": list(rounds_grid), "suboptimality": [float(v) for v in y],
        "l_star": l_star, "eps_floor_A": float(coef[0]),
        "rate_B": float(coef[1]), "r2": float(1 - ss_res / ss_tot),
        "monotone_decreasing": bool(np.all(np.diff(y) < 1e-3)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    r = run(seed=args.seed)
    print(f"L* ~= {r['l_star']:.4f}")
    for R, g in zip(r["rounds"], r["suboptimality"]):
        print(f"  R={R:3d}  L(w_R)-L* = {g:.4f}")
    print(f"fit: gap ~= {r['eps_floor_A']:.4f} + {r['rate_B']:.3f}/R "
          f"(R^2={r['r2']:.3f})")
    print(f"monotone decreasing: {r['monotone_decreasing']}")
    return r


if __name__ == "__main__":
    main()
