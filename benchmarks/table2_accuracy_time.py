"""Table 2: test accuracy + normalized mean round time for the four
strategies at 10% / 30% stragglers across the benchmarks."""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.flbench import STRATEGY_NAMES, run_benchmark


def run(benches=("synthetic_1_1", "synthetic_0505", "synthetic_0_0"),
        scale: str = "tiny", straggler_pcts=(10.0, 30.0), seed: int = 0,
        verbose: bool = False):
    rows = []
    for bench in benches:
        for pct in straggler_pcts:
            res = run_benchmark(bench, scale, pct, seed, verbose=verbose)
            for name in STRATEGY_NAMES:
                s = res[name]["summary"]
                rows.append({
                    "bench": bench, "stragglers_pct": pct, "strategy": name,
                    "test_acc": round(s["final_test_acc"], 4),
                    "best_acc": round(s["best_test_acc"], 4),
                    "mean_round_time_norm":
                        round(s["mean_round_time_normalized"], 3),
                    "exceeds_deadline":
                        s["max_round_time_normalized"] > 1.001,
                })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "paper"])
    ap.add_argument("--benches", nargs="+",
                    default=["synthetic_1_1", "synthetic_0505",
                             "synthetic_0_0"])
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    t0 = time.perf_counter()
    rows = run(tuple(args.benches), args.scale, verbose=args.verbose)
    print(f"{'bench':16s} {'s%':4s} {'strategy':10s} {'acc':7s} "
          f"{'t/round(norm)':13s} {'>tau'}")
    for r in rows:
        print(f"{r['bench']:16s} {r['stragglers_pct']:4.0f} "
              f"{r['strategy']:10s} {r['test_acc']:7.4f} "
              f"{r['mean_round_time_norm']:13.3f} "
              f"{'YES' if r['exceeds_deadline'] else 'no'}")
    print(f"# table2 wall time: {time.perf_counter()-t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
