"""Fig. 4 / Fig. 7: distribution of per-client round completion times.

Reports percentiles of client round time (normalized by the deadline τ) per
strategy — FedAvg's tail stretches past τ while the deadline-aware methods
cluster at/below 1.0, FedCore closest to 1.0 (best utilization).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.flbench import STRATEGY_NAMES, run_benchmark


def run(bench: str = "synthetic_1_1", scale: str = "tiny",
        straggler_pct: float = 30.0, seed: int = 0):
    res = run_benchmark(bench, scale, straggler_pct, seed)
    stats = {}
    for name in STRATEGY_NAMES:
        out = res[name]
        tau = out["deadline"]
        times = np.array([t for h in out["history"]
                          for t in h.client_times]) / tau
        stats[name] = {
            "p50": float(np.percentile(times, 50)),
            "p90": float(np.percentile(times, 90)),
            "p99": float(np.percentile(times, 99)),
            "max": float(times.max()),
            "mean": float(times.mean()),
            "frac_over_deadline": float((times > 1.0).mean()),
        }
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="synthetic_1_1")
    ap.add_argument("--scale", default="tiny")
    ap.add_argument("--stragglers", type=float, default=30.0)
    args = ap.parse_args(argv)
    stats = run(args.bench, args.scale, args.stragglers)
    print(f"{'strategy':10s} {'p50':>6s} {'p90':>6s} {'p99':>6s} "
          f"{'max':>6s} {'>tau%':>6s}   (client time / tau)")
    for name, s in stats.items():
        print(f"{name:10s} {s['p50']:6.2f} {s['p90']:6.2f} {s['p99']:6.2f} "
              f"{s['max']:6.2f} {100*s['frac_over_deadline']:5.1f}%")
    return stats


if __name__ == "__main__":
    main()
