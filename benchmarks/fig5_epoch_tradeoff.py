"""Fig. 5: why FedCore converges faster than FedProx — stragglers under
FedCore still take E full gradient-exploration epochs (on the coreset),
while FedProx truncates to fewer full-set epochs.  We count effective
optimization epochs per straggler round and the resulting loss after a
fixed simulated-time budget."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.flbench import build_world, make_strategy
from repro.fed.simulator import straggler_mask


def run(bench: str = "synthetic_1_1", scale: str = "tiny",
        straggler_pct: float = 30.0, seed: int = 0):
    world = build_world(bench, scale, straggler_pct, seed)
    from repro.fed.simulator import straggler_deadline
    tau = straggler_deadline(world.specs, world.cfg.epochs,
                             world.cfg.straggler_pct)
    mask = straggler_mask(world.specs, world.cfg.epochs, tau)
    stragglers = [i for i, m in enumerate(mask) if m]

    rng = np.random.default_rng(seed)
    import jax
    params = world.model.init(jax.random.PRNGKey(seed))
    rows = []
    for name in ("fedprox", "fedcore"):
        strat = make_strategy(name, world)
        for cid in stragglers[:4]:
            res = strat.local_update(params, world.train[cid],
                                     world.specs[cid], tau,
                                     world.cfg.epochs, rng)
            rows.append({
                "strategy": name, "client": cid,
                "m": world.specs[cid].m,
                "epochs_done": round(res.epochs_done, 2),
                "coreset_size": res.coreset_size,
                "final_loss": round(res.final_loss, 4),
                "time/tau": round(res.sim_time / tau, 3),
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="synthetic_1_1")
    ap.add_argument("--scale", default="tiny")
    args = ap.parse_args(argv)
    rows = run(args.bench, args.scale)
    print(f"{'strategy':9s} {'client':>6s} {'m':>5s} {'epochs':>7s} "
          f"{'coreset':>8s} {'loss':>8s} {'t/tau':>6s}")
    for r in rows:
        print(f"{r['strategy']:9s} {r['client']:6d} {r['m']:5d} "
              f"{r['epochs_done']:7.2f} {r['coreset_size']:8d} "
              f"{r['final_loss']:8.4f} {r['time/tau']:6.3f}")
    return rows


if __name__ == "__main__":
    main()
