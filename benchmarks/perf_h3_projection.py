"""§Perf H3 — hillclimbing the paper's own technique at LM scale.

The FedCore hot-spot is the (m, m) gradient-distance matrix: O(m²·F) FLOPs
with F = d_model (12288 for a mistral-large silo).  Hypothesis: a JL random
projection of the gradient features to F' « F cuts the distance-matrix cost
by F/F' while leaving the k-medoids *selection quality* (the ε of
Assumption A.3) essentially unchanged, because JL preserves pairwise
distances to (1±δ).

This benchmark MEASURES selection quality (ε on exact per-sample gradients,
coreset overlap) and CPU wall time vs projection dim, and reports the
analytic TPU-kernel roofline for the full-scale silo.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coreset import build_coreset, coreset_epsilon
from repro.core.gradients import (grad_features, project_features,
                                  true_per_sample_grads)
from repro.data.synthetic import synthetic_dataset
from repro.models.small import LogisticRegression

# full-scale silo parameters for the analytic roofline
SILO_M = 65536          # sequences per silo (train_4k per silo)
SILO_F = 12288          # mistral-large d_model
PEAK = 197e12
HBM = 819e9


def analytic_kernel_roofline(m: int, f: int):
    flops = 2.0 * m * m * f          # cross-term matmul
    bytes_ = (2.0 * m * f + 4.0 * m * m)  # read X twice (tiled), write D f32
    return {"flops": flops, "bytes": bytes_,
            "t_compute_s": flops / PEAK, "t_memory_s": bytes_ / HBM,
            "intensity": flops / bytes_}


def run(m: int = 160, budget: int = 24, dims=(None, 256, 64, 16),
        seed: int = 0):
    # CNN with high-dim last-layer-grad features (F = 7*7*32 = 1568) — the
    # regime where projection matters
    from repro.data.mnist_like import mnist_like_dataset
    from repro.models.small import SmallCNN
    clients = mnist_like_dataset(n_clients=1, mean_samples=m, std_samples=1,
                                 seed=seed)
    data = {k: jnp.asarray(v[:m]) for k, v in clients[0].items()}
    m = len(data["y"])
    model = SmallCNN()
    params = model.init(jax.random.PRNGKey(seed))
    from repro.models.training import make_train_step
    from repro.optim.optimizers import sgd
    opt = sgd(0.03)
    step = make_train_step(model.loss, opt, donate=False)
    st = opt.init(params)
    for _ in range(5):
        params, st, _ = step(params, st, data)

    feats = grad_features(model, params, data)
    grads = jnp.asarray(true_per_sample_grads(model.loss, params, data))
    base = build_coreset(feats, budget)
    base_idx = set(np.asarray(base.indices).tolist())

    rows = []
    for dim in dims:
        t0 = time.perf_counter()
        cs = build_coreset(feats, budget, projection_dim=dim)
        jax.block_until_ready(cs.indices)
        dt = time.perf_counter() - t0
        eps = float(coreset_epsilon(grads, cs))
        overlap = len(base_idx
                      & set(np.asarray(cs.indices).tolist())) / budget
        rows.append({"projection_dim": dim or feats.shape[1],
                     "epsilon": eps, "overlap_with_exact": overlap,
                     "wall_s": dt})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=240)
    args = ap.parse_args(argv)
    rows = run(args.m)
    print(f"{'F_proj':>7s} {'epsilon':>10s} {'overlap':>8s} {'wall':>8s}")
    for r in rows:
        print(f"{r['projection_dim']:7d} {r['epsilon']:10.5f} "
              f"{100*r['overlap_with_exact']:7.0f}% {r['wall_s']*1e3:6.0f}ms")
    print("\n# analytic TPU-v5e kernel roofline for a full-scale silo "
          f"(m={SILO_M}, F={SILO_F}):")
    for f in (SILO_F, 256, 64):
        r = analytic_kernel_roofline(SILO_M, f)
        dom = "compute" if r["t_compute_s"] > r["t_memory_s"] else "memory"
        print(f"  F={f:6d}: {r['flops']:.2e} FLOPs, "
              f"compute {r['t_compute_s']*1e3:8.2f}ms, "
              f"memory {r['t_memory_s']*1e3:8.2f}ms -> {dom}-bound")
    return rows


if __name__ == "__main__":
    main()
