"""§4.2 claim: coreset generation runs "within one second" for large
datasets.  Times the full selection path (feature extraction excluded —
the paper gets features free from the first epoch): pairwise distances +
k-medoids, for both the numpy FasterPAM oracle and the JAX on-device
solver, plus the Pallas pairwise kernel (interpret mode on CPU; compiled
on real TPU)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coreset import build_coreset
from repro.core.kmedoids import kmedoids_jax, kmedoids_numpy, pairwise_sq_dists


def _time(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            else None
    return (time.perf_counter() - t0) / repeats


def run(sizes=(256, 1024, 2048), d: int = 128, budget_frac: float = 0.1):
    rows = []
    rng = np.random.default_rng(0)
    for m in sizes:
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        k = max(2, int(m * budget_frac))

        t_dist = _time(lambda: jax.block_until_ready(
            pairwise_sq_dists(x)))
        D = np.sqrt(np.maximum(np.asarray(pairwise_sq_dists(x)), 0.0))
        Dj = jnp.asarray(D)

        t_np = _time(kmedoids_numpy, D, k, repeats=1)
        t_jax = _time(lambda: jax.block_until_ready(
            kmedoids_jax(Dj, k)), repeats=1)
        t_full = _time(lambda: jax.block_until_ready(
            build_coreset(x, k).indices), repeats=1)
        rows.append({"m": m, "k": k, "t_pairwise_s": t_dist,
                     "t_kmedoids_numpy_s": t_np, "t_kmedoids_jax_s": t_jax,
                     "t_full_selection_s": t_full,
                     "under_1s": t_full < 1.0})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", nargs="+", type=int,
                    default=[256, 1024, 2048])
    args = ap.parse_args(argv)
    rows = run(tuple(args.sizes))
    print(f"{'m':>6s} {'k':>5s} {'pairwise':>10s} {'kmed(np)':>10s} "
          f"{'kmed(jax)':>10s} {'full':>10s} {'<1s'}")
    for r in rows:
        print(f"{r['m']:6d} {r['k']:5d} {r['t_pairwise_s']*1e3:8.1f}ms "
              f"{r['t_kmedoids_numpy_s']*1e3:8.1f}ms "
              f"{r['t_kmedoids_jax_s']*1e3:8.1f}ms "
              f"{r['t_full_selection_s']*1e3:8.1f}ms "
              f"{'YES' if r['under_1s'] else 'no'}")
    return rows


if __name__ == "__main__":
    main()
