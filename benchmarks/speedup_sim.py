"""The 8x headline: expected round-time reduction vs FedAvg at PAPER scale.

The paper's training-time claim is a property of the *timing model* (round
time = max over selected clients of work/capability), so it can be
reproduced exactly at the published scale (1000 MNIST clients, K=100,
E=10, cⁱ~N(1,0.25), power-law mⁱ) without running the actual training —
each strategy's per-client work model is applied to the same sampled
worlds.  This is the full-scale companion to the (reduced-scale) live FL
runs in table2_accuracy_time.py.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.data.partition import power_law_sizes
from repro.fed.simulator import make_client_specs, straggler_deadline
from repro.fed.strategies import FORWARD_FRAC


def simulate(bench: str = "mnist", straggler_pct: float = 30.0,
             rounds: int = 500, seed: int = 0):
    params = {
        "mnist": dict(n=1000, mean=69, std=106, k=100, epochs=10),
        "shakespeare": dict(n=143, mean=3616, std=6808, k=10, epochs=10),
        "synthetic": dict(n=30, mean=670, std=1148, k=10, epochs=10),
    }[bench]
    rng = np.random.default_rng(seed)
    sizes = power_law_sizes(params["n"], params["mean"], params["std"], rng)
    specs = make_client_specs(sizes, rng)
    E = params["epochs"]
    tau = straggler_deadline(specs, E, straggler_pct)

    m = np.array([s.m for s in specs], float)
    c = np.array([s.c for s in specs], float)
    p = m / m.sum()

    def fedcore_time(i):
        if E * m[i] <= c[i] * tau:
            return E * m[i] / c[i]
        if c[i] * tau > m[i] and E > 1:
            b = max(1, min(int((c[i] * tau - m[i]) // (E - 1)), int(m[i])))
            w = m[i] + (E - 1) * b
            if w <= c[i] * tau:
                return w / c[i]
        avail = c[i] * tau - FORWARD_FRAC * m[i]
        b = max(1, min(int(avail // E), int(m[i])))
        ep = max(1, min(E, int(avail // b)))
        return (FORWARD_FRAC * m[i] + ep * b) / c[i]

    fedavg, fedcore, fedprox, fedavg_ds = [], [], [], []
    for _ in range(rounds):
        sel = rng.choice(params["n"], size=params["k"], replace=True, p=p)
        t_full = E * m[sel] / c[sel]
        fedavg.append(t_full.max())
        fedavg_ds.append(min(t_full.max(), tau))
        fedprox.append(np.minimum(t_full, tau).max())
        fedcore.append(max(fedcore_time(i) for i in sel))
    out = {
        "tau": tau,
        "fedavg_mean_norm": float(np.mean(fedavg) / tau),
        "fedavg_ds_mean_norm": float(np.mean(fedavg_ds) / tau),
        "fedprox_mean_norm": float(np.mean(fedprox) / tau),
        "fedcore_mean_norm": float(np.mean(fedcore) / tau),
        "speedup_vs_fedavg": float(np.mean(fedavg) / np.mean(fedcore)),
        "fedavg_p99_norm": float(np.percentile(fedavg, 99) / tau),
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=500)
    args = ap.parse_args(argv)
    print(f"{'bench':12s} {'s%':>4s} {'fedavg':>8s} {'ds':>6s} "
          f"{'prox':>6s} {'core':>6s} {'speedup':>8s}  (mean t/tau)")
    for bench in ("mnist", "shakespeare", "synthetic"):
        for pct in (10.0, 30.0):
            r = simulate(bench, pct, rounds=args.rounds)
            print(f"{bench:12s} {pct:4.0f} {r['fedavg_mean_norm']:8.2f} "
                  f"{r['fedavg_ds_mean_norm']:6.2f} "
                  f"{r['fedprox_mean_norm']:6.2f} "
                  f"{r['fedcore_mean_norm']:6.2f} "
                  f"{r['speedup_vs_fedavg']:7.2f}x")
    return 0


if __name__ == "__main__":
    main()
