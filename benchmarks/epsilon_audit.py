"""Assumption A.3 audit: measure the ε-approximation of FedCore coresets on
exact per-sample gradients, vs budget and vs a random-subset baseline —
the empirical backbone of Theorem 5.1's O(ε) + O(1/R) bound."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coreset import build_coreset, coreset_epsilon
from repro.core.gradients import grad_features, true_per_sample_grads
from repro.data.synthetic import synthetic_dataset
from repro.models.small import LogisticRegression


def run(m: int = 200, budgets=(5, 10, 20, 50, 100), seed: int = 0):
    clients = synthetic_dataset(0.5, 0.5, n_clients=1, mean_samples=m,
                                std_samples=1, seed=seed)
    data = {k: jnp.asarray(v[:m]) for k, v in clients[0].items()}
    m = len(data["y"])
    model = LogisticRegression()
    params = model.init(jax.random.PRNGKey(seed))
    # a few SGD steps so gradients are non-trivial
    from repro.models.training import make_train_step
    from repro.optim.optimizers import sgd
    opt = sgd(0.1)
    step = make_train_step(model.loss, opt, donate=False)
    st = opt.init(params)
    for _ in range(5):
        params, st, _ = step(params, st, data)

    feats = grad_features(model, params, data)
    grads = jnp.asarray(true_per_sample_grads(model.loss, params, data))
    rng = np.random.default_rng(seed)
    rows = []
    for b in budgets:
        b = min(b, m)
        cs = build_coreset(feats, b)
        eps = float(coreset_epsilon(grads, cs))
        # random-subset baseline (importance weight m/b)
        rand = []
        for _ in range(5):
            idx = rng.choice(m, size=b, replace=False)
            approx = np.asarray(grads[idx]).sum(0) * (m / b)
            rand.append(np.linalg.norm(np.asarray(grads).sum(0) - approx) / m)
        rows.append({"budget": b, "epsilon": eps,
                     "epsilon_random": float(np.mean(rand)),
                     "gain": float(np.mean(rand)) / max(eps, 1e-12)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=200)
    args = ap.parse_args(argv)
    rows = run(args.m)
    print(f"{'budget':>7s} {'eps(coreset)':>13s} {'eps(random)':>12s} "
          f"{'gain':>6s}")
    for r in rows:
        print(f"{r['budget']:7d} {r['epsilon']:13.5f} "
              f"{r['epsilon_random']:12.5f} {r['gain']:6.2f}x")
    return rows


if __name__ == "__main__":
    main()
