"""Benchmark driver: one entry per paper table/figure + the roofline report.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract,
followed by the human-readable tables.  Scale defaults to `tiny` so the
whole suite completes on the single CPU core of this container; pass
``--scale paper`` on real hardware for the published settings.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _timed(name, fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    dt = time.perf_counter() - t0
    return name, dt, out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "paper"])
    ap.add_argument("--skip-fl", action="store_true",
                    help="only run the cheap benchmarks")
    ap.add_argument("--dryrun-jsonl", default="results/dryrun_single.jsonl")
    args = ap.parse_args(argv)

    from benchmarks import (convergence_rate, coreset_overhead,
                            epsilon_audit, fig3_convergence,
                            fig4_round_distribution, fig5_epoch_tradeoff,
                            perf_h3_projection, roofline, speedup_sim,
                            table2_accuracy_time)

    results = []
    print("=" * 72)
    print("## speedup_sim (paper-scale timing model; the '8x' headline)")
    results.append(_timed("speedup_sim", speedup_sim.main, []))
    print("=" * 72)
    print("## coreset_overhead (paper §4.2 '<1 s' claim)")
    results.append(_timed("coreset_overhead", coreset_overhead.main, []))
    print("=" * 72)
    print("## epsilon_audit (Assumption A.3 / Theorem 5.1)")
    results.append(_timed("epsilon_audit", epsilon_audit.main, []))
    print("=" * 72)
    print("## perf_h3_projection (§Perf H3: JL-projected selection)")
    results.append(_timed("perf_h3_projection", perf_h3_projection.main,
                          []))

    if not args.skip_fl:
        print("=" * 72)
        print(f"## table2_accuracy_time (scale={args.scale})")
        results.append(_timed(
            "table2_accuracy_time", table2_accuracy_time.main,
            ["--scale", args.scale]))
        print("=" * 72)
        print("## fig3_convergence")
        results.append(_timed("fig3_convergence", fig3_convergence.main,
                              ["--scale", args.scale]))
        print("=" * 72)
        print("## fig4_round_distribution")
        results.append(_timed("fig4_round_distribution",
                              fig4_round_distribution.main,
                              ["--scale", args.scale]))
        print("=" * 72)
        print("## fig5_epoch_tradeoff")
        results.append(_timed("fig5_epoch_tradeoff",
                              fig5_epoch_tradeoff.main,
                              ["--scale", args.scale]))
        print("=" * 72)
        print("## convergence_rate (Theorem 5.1: O(eps) + O(1/R))")
        results.append(_timed("convergence_rate", convergence_rate.main,
                              []))

    print("=" * 72)
    print("## roofline (single-pod 16x16; see EXPERIMENTS.md §Roofline)")
    dr = args.dryrun_jsonl if os.path.exists(args.dryrun_jsonl) else None
    results.append(_timed("roofline", roofline.main,
                          (["--dryrun", dr] if dr else [])))

    print("=" * 72)
    print("name,us_per_call,derived")
    for name, dt, _ in results:
        print(f"{name},{dt*1e6:.0f},wall_s={dt:.2f}")


if __name__ == "__main__":
    main()
