"""Batched serving driver: prefill + KV-cache decode with greedy/temperature
sampling for any architecture config.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import PRESETS
from repro.models.model import Model


def generate(model: Model, params, prompts: jnp.ndarray, gen: int,
             temperature: float = 0.0, seed: int = 0, cache_len: int = 0):
    """prompts: (B, P) int32 -> (B, P+gen) tokens."""
    cfg = model.cfg
    b, p_len = prompts.shape
    cache_len = cache_len or (p_len + gen)
    state = model.init_decode_state(params, b, cache_len, dtype=jnp.float32)

    decode = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(seed)
    tokens = [prompts]
    logits = None
    # prefill token-by-token through the decode path (cache-exact)
    for t in range(p_len):
        logits, state = decode(params, state, prompts[:, t:t + 1],
                               jnp.asarray(t, jnp.int32))
    cur = _sample(logits, temperature, key)
    for t in range(gen):
        tokens.append(cur)
        logits, state = decode(params, state, cur,
                               jnp.asarray(p_len + t, jnp.int32))
        key, sub = jax.random.split(key)
        cur = _sample(logits, temperature, sub)
    return jnp.concatenate(tokens, axis=1)


def _sample(logits, temperature, key):
    if temperature <= 0:
        return jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(
            jnp.int32)
    p = logits[:, -1, :] / temperature
    return jax.random.categorical(key, p, axis=-1)[:, None].astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny",
                    choices=list(PRESETS) + ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (required on CPU)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (PRESETS[args.arch] if args.arch in PRESETS
           else get_config(args.arch, smoke=args.smoke))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, args.gen, args.temperature,
                   args.seed)
    dt = time.perf_counter() - t0
    tput = args.batch * args.gen / dt
    print(f"[serve] arch={cfg.arch_id} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} "
          f"-> {out.shape} in {dt:.2f}s ({tput_str(tput)})")
    print("[serve] sample row:", np.asarray(out[0])[:24].tolist())
    return out


def tput_str(tput: float) -> str:
    return f"{tput:,.1f} tok/s"


if __name__ == "__main__":
    main()
