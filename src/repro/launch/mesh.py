"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the single real device (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axis names that carry the batch (pod + data when present)."""
    names = mesh.axis_names
    return tuple(n for n in ("pod", "data") if n in names)


MESH_SPECS = {
    "single": dict(multi_pod=False, chips=256,
                   desc="16x16 (data, model) — one v5e pod"),
    "multi": dict(multi_pod=True, chips=512,
                  desc="2x16x16 (pod, data, model) — two v5e pods over DCN"),
}
