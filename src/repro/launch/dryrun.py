"""Multi-pod dry-run: prove the distribution config is coherent without
real hardware (deliverable e).

For every (architecture x input-shape x mesh) combination this lowers and
compiles the real step function — ``train_step`` for train shapes, forward
for prefill, ``serve_step`` (one token against a full-length KV/SSM cache)
for decode shapes — with the production sharding rules, then records:

  * ``compiled.memory_analysis()``  (bytes per device — proves it fits)
  * ``compiled.cost_analysis()``    (HLO FLOPs / bytes for the roofline)
  * collective bytes parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single \
      --out results/dryrun
Failures here (sharding mismatch, unsupported collective) are bugs in the
system, not in the harness.
"""
import argparse
import json
import os
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

# The production mesh wants 512 virtual host devices (a multi-pod topology
# simulated on CPU).  Forcing them is a process-global XLA setting, so it
# only happens when this module IS the program (``python -m
# repro.launch.dryrun`` executes it as ``__main__``) or on explicit
# opt-in via REPRO_DRYRUN_FORCE_DEVICES=N — importing the module as a
# library must not reconfigure the host's device count as an import-time
# side effect.  XLA reads the flag at backend init (first jax use), so
# setting it here — after the package imports above already pulled in
# jax — is still in time.
if __name__ == "__main__" or os.environ.get("REPRO_DRYRUN_FORCE_DEVICES"):
    from repro.utils.xla_env import force_host_devices_here
    force_host_devices_here(
        int(os.environ.get("REPRO_DRYRUN_FORCE_DEVICES", "512")))

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.distributed.sharding import (batch_specs, decode_state_specs,
                                        param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.optimizers import sgd, adam
from repro.utils.tree import tree_add

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op in optimized HLO.

    NOTE: ops inside while-loop bodies (layer scans) appear once in the
    text regardless of trip count — these are lower bounds; the roofline
    harness (benchmarks/roofline.py) scales loop-body collectives by the
    known layer counts via its analytic model.
    """
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue
        per_op[m.group(2)] += _shape_bytes(m.group(1))
        counts[m.group(2)] += 1
    return {"bytes_by_op": per_op, "counts": counts,
            "total_bytes": sum(per_op.values())}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_step(model: Model, shape: ShapeConfig, optimizer: str = "sgd"):
    """Returns (step_fn, example_args builder) for the shape kind."""
    cfg = model.cfg
    if shape.kind == "train":
        opt = adam(1e-4) if optimizer == "adam" else sgd(1e-2)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                loss, _ = model.loss(p, batch)
                return loss

            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = tree_add(params, updates)
            return params, opt_state, loss

        return train_step, opt
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, aux, hidden = model.forward(params, batch)
            # serving prefill returns last-position logits
            return logits[:, -1, :]

        return prefill_step, None

    def serve_step(params, state, batch):
        return model.decode_step(params, state, batch["token"], batch["pos"])

    return serve_step, None


def abstract_params(model: Model, dtype=DTYPE):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), shapes)


def _named(specs_tree, mesh):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------

def dry_run(arch_id: str, shape_name: str, multi_pod: bool = False,
            sharding_mode: str = "tp", optimizer: str = "sgd",
            context_parallel: bool = False, remat: bool = False,
            mesh_split: Optional[tuple] = None,
            verbose: bool = True) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = get_config(arch_id, shape=shape)
    if remat:
        cfg = cfg.with_(remat=True)
    model = Model(cfg)
    if mesh_split is not None:
        # perf-iteration rebalance: same 256 chips, different (data, model)
        assert mesh_split[0] * mesh_split[1] == 256
        mesh = jax.make_mesh(mesh_split, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    record: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(mesh.shape),
        "sharding": sharding_mode,
        "context_parallel": context_parallel,
        "remat": remat,
        "optimizer": optimizer if shape.kind == "train" else None,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    t0 = time.perf_counter()

    params_abs = abstract_params(model)
    p_specs = param_specs(cfg, params_abs, mesh, mode=sharding_mode)
    in_specs = model.input_specs(shape, dtype=DTYPE)
    b_specs = batch_specs(in_specs, mesh)

    step, opt = build_step(model, shape, optimizer)

    with mesh:
        if shape.kind == "train":
            opt_abs = jax.eval_shape(opt.init, params_abs)
            o_specs = _opt_specs(opt_abs, p_specs)
            jitted = jax.jit(
                step,
                in_shardings=(_named(p_specs, mesh), _named(o_specs, mesh),
                              _named(b_specs, mesh)),
                out_shardings=(_named(p_specs, mesh), _named(o_specs, mesh),
                               NamedSharding(mesh, P())))
            lowered = jitted.lower(params_abs, opt_abs, in_specs)
        elif shape.kind == "prefill":
            from repro.distributed.sharding import _fit
            out_spec = _fit(
                P(tuple(n for n in ("pod", "data") if n in mesh.axis_names),
                  "model"),
                (shape.global_batch, cfg.vocab_size), mesh)
            jitted = jax.jit(
                step,
                in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh)),
                out_shardings=NamedSharding(mesh, out_spec))
            lowered = jitted.lower(params_abs, in_specs)
        else:  # decode
            state_abs = jax.eval_shape(
                lambda p: model.init_decode_state(
                    p, shape.global_batch, shape.seq_len, dtype=DTYPE),
                params_abs)
            s_specs = decode_state_specs(cfg, state_abs, mesh,
                                         context_parallel=context_parallel)
            jitted = jax.jit(
                step,
                in_shardings=(_named(p_specs, mesh), _named(s_specs, mesh),
                              _named(b_specs, mesh)),
                out_shardings=(NamedSharding(mesh, P()),
                               _named(s_specs, mesh)))
            lowered = jitted.lower(params_abs, state_abs, in_specs)

        record["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_s"] = round(time.perf_counter() - t1, 2)

    mem = compiled.memory_analysis()
    record["memory"] = _memory_dict(mem)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per device
        cost = cost[0] if cost else None
    record["cost"] = {k: v for k, v in cost.items()
                      if k in ("flops", "bytes accessed")} if cost else {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    record["collectives"] = collective_stats(hlo)
    record["hlo_lines"] = hlo.count("\n")
    record["ok"] = True
    if verbose:
        n_dev = int(jnp.prod(jnp.asarray(list(mesh.shape.values()))))
        print(f"[dryrun] {arch_id} x {shape_name} x "
              f"{record['mesh']} ({sharding_mode}) OK — "
              f"lower {record['lower_s']}s compile {record['compile_s']}s "
              f"mem/device "
              f"{record['memory'].get('bytes_per_device', 0)/2**30:.2f} GiB "
              f"flops {record['cost'].get('flops', 0):.3e} "
              f"coll {record['collectives']['total_bytes']/2**30:.2f} GiB",
              flush=True)
    return record


def _opt_specs(opt_abs, p_specs):
    """Optimizer-state sharding: momentum-like trees mirror the params."""
    def build(node, spec_node):
        return spec_node

    out = {}
    for k, v in opt_abs.items():
        if k in ("m", "v", "mu"):
            out[k] = p_specs
        else:
            out[k] = jax.tree.map(lambda _: P(), v)
    return out


def _memory_dict(mem) -> Dict[str, float]:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    total = (out.get("argument_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)
             - out.get("alias_size_in_bytes", 0))
    out["bytes_per_device"] = total
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) pair")
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adam"])
    ap.add_argument("--context-parallel", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--mesh-split", default=None,
                    help="perf iteration: 'DATA,MODEL' split of 256 chips "
                         "(e.g. 32,8)")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args(argv)
    mesh_split = (tuple(int(x) for x in args.mesh_split.split(","))
                  if args.mesh_split else None)

    pairs = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                pairs.append((a, s, m))

    records = []
    failures = 0
    for arch, shp, mesh_kind in pairs:
        try:
            rec = dry_run(arch, shp, multi_pod=(mesh_kind == "multi"),
                          sharding_mode=args.sharding,
                          optimizer=args.optimizer,
                          context_parallel=args.context_parallel,
                          remat=args.remat, mesh_split=mesh_split)
        except Exception as e:  # a failure here is a bug in the system
            failures += 1
            rec = {"arch": arch, "shape": shp, "mesh": mesh_kind,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] {arch} x {shp} x {mesh_kind} FAILED: {e}",
                  flush=True)
        records.append(rec)
        if args.out:
            os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                        exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    ok = sum(1 for r in records if r.get("ok"))
    print(f"[dryrun] {ok}/{len(records)} combinations compiled",
          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
