"""End-to-end training driver (single-host or mesh).

Trains a decoder LM (any assigned arch id, or a named preset) on a synthetic
token stream, with optional **FedCore-for-LM**: the stream is split into
"client silos"; silos whose per-round token budget exceeds their simulated
capability train on a coreset selected by last-layer-gradient k-medoids —
the paper's algorithm applied at LM scale.

Examples:
  # plain centralized training, ~100M params, a few hundred steps
  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300

  # smoke scale (CI)
  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 10

  # federated with coresets (4 silos, 30% stragglers)
  PYTHONPATH=src python -m repro.launch.train --preset tiny --fedcore \
      --silos 4 --rounds 3 --steps 8
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_server_state
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.training import make_train_step
from repro.optim.optimizers import adam, sgd
from repro.optim.schedules import warmup_cosine_lr
from repro.utils.tree import param_count, tree_weighted_mean

PRESETS = {
    "tiny": ModelConfig(arch_id="tiny-lm", family="dense", n_layers=2,
                        d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                        vocab_size=512),
    "20m": ModelConfig(arch_id="lm-20m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
                       vocab_size=8192),
    "100m": ModelConfig(arch_id="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                        vocab_size=32768),
}


def synthetic_stream(vocab: int, batch: int, seq: int, seed: int = 0):
    """Markov-ish synthetic token batches (learnable structure)."""
    rng = np.random.default_rng(seed)
    # sparse bigram table
    nxt = rng.integers(0, vocab, size=(vocab, 4))
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        choice = rng.integers(0, 4, size=(batch, seq))
        noise = rng.random((batch, seq)) < 0.1
        rand = rng.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            nx = nxt[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nx)
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:]),
               "weights": jnp.ones((batch,), jnp.float32)}


def train_centralized(cfg: ModelConfig, steps: int, batch: int, seq: int,
                      lr: float, ckpt_dir: Optional[str], log_every: int,
                      seed: int) -> Dict:
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n = param_count(params)
    print(f"[train] arch={cfg.arch_id} params={n/1e6:.1f}M "
          f"batch={batch} seq={seq}")
    opt = adam(warmup_cosine_lr(lr, max(1, steps // 20), steps))
    step_fn = make_train_step(model.loss, opt, clip_norm=1.0, donate=False)
    opt_state = opt.init(params)
    stream = synthetic_stream(cfg.vocab_size, batch, seq, seed)
    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch_data = next(stream)
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            dt = time.perf_counter() - t0
            tput = (i + 1) * batch * seq / dt
            print(f"[train] step {i:5d} loss {losses[-1]:.4f} "
                  f"({tput:,.0f} tok/s)", flush=True)
    if ckpt_dir:
        save_server_state(ckpt_dir, steps, params,
                          extra={"arch": cfg.arch_id,
                                 "final_loss": losses[-1]})
        print(f"[train] checkpoint written to {ckpt_dir}")
    assert losses[-1] < losses[0], "training did not reduce loss"
    return {"initial_loss": losses[0], "final_loss": losses[-1],
            "losses": losses}


def train_fedcore_lm(cfg: ModelConfig, rounds: int, steps_per_epoch: int,
                     silos: int, batch: int, seq: int, lr: float,
                     straggler_pct: float, seed: int) -> Dict:
    """Federated LM fine-tuning with FedCore coreset selection per silo.

    Each silo holds `steps_per_epoch * batch` sequences; stragglers (slow
    silos) select a sequence-coreset via last-layer-gradient k-medoids and
    train on it with weights δ — Alg. 1 at LM granularity.
    """
    from repro.core.coreset import build_coreset, coreset_batch
    from repro.models.small import _last_layer_grad_feature

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    opt = sgd(lr)
    step_fn = make_train_step(model.loss, opt, donate=False)

    # build silo datasets
    stream = synthetic_stream(cfg.vocab_size, batch, seq, seed)
    silo_data = []
    for s in range(silos):
        seqs = [next(stream) for _ in range(steps_per_epoch)]
        silo_data.append({
            "tokens": jnp.concatenate([b["tokens"] for b in seqs]),
            "labels": jnp.concatenate([b["labels"] for b in seqs]),
        })
    caps = np.maximum(rng.normal(1.0, 0.5, silos), 0.2)
    m = steps_per_epoch * batch  # sequences per silo
    epochs = 2
    times_full = epochs * m / caps
    tau = float(np.percentile(times_full, 100 - straggler_pct))

    @jax.jit
    def features_fn(p, data):
        logits, _, hidden = model.forward(p, data)
        w = p["embed"].T if cfg.tie_embeddings else p["w_unembed"]
        return _last_layer_grad_feature(logits, data["labels"], w)

    history = []
    for r in range(rounds):
        local_params = []
        round_time = 0.0
        n_core = 0
        for s in range(silos):
            data = silo_data[s]
            needs = epochs * m > caps[s] * tau
            p_local = params
            opt_state = opt.init(p_local)
            if needs:
                feats = features_fn(params, data)
                budget = max(2, int((caps[s] * tau - m) // max(epochs - 1,
                                                               1)))
                budget = min(budget, m)
                cs = build_coreset(feats, budget)
                cdata = coreset_batch(
                    {k: np.asarray(v) for k, v in data.items()}, cs, m)
                n_core += 1
                t = (m + (epochs - 1) * budget) / caps[s]
                # 1 full epoch + (E-1) coreset epochs
                for lo in range(0, m, batch):
                    bt = {k: v[lo:lo + batch] for k, v in data.items()}
                    bt["weights"] = jnp.ones((bt["tokens"].shape[0],))
                    p_local, opt_state, met = step_fn(p_local, opt_state, bt)
                for _ in range(epochs - 1):
                    bt = {k: jnp.asarray(v) for k, v in cdata.items()}
                    p_local, opt_state, met = step_fn(p_local, opt_state, bt)
            else:
                t = epochs * m / caps[s]
                for _ in range(epochs):
                    for lo in range(0, m, batch):
                        bt = {k: v[lo:lo + batch] for k, v in data.items()}
                        bt["weights"] = jnp.ones((bt["tokens"].shape[0],))
                        p_local, opt_state, met = step_fn(p_local, opt_state,
                                                          bt)
            local_params.append(p_local)
            round_time = max(round_time, t)
        params = tree_weighted_mean(local_params, [1.0] * silos)
        loss = float(met["loss"])
        history.append({"round": r, "loss": loss,
                        "round_time": round_time, "tau": tau,
                        "coreset_silos": n_core})
        print(f"[fedcore-lm] round {r} loss {loss:.4f} "
              f"time/tau {round_time/tau:.3f} coreset silos {n_core}",
              flush=True)
    assert all(h["round_time"] <= tau * 1.001 for h in history), \
        "FedCore round exceeded deadline"
    return {"history": history}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny",
                    choices=list(PRESETS) + ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    # federated mode
    ap.add_argument("--fedcore", action="store_true")
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--straggler-pct", type=float, default=30.0)
    args = ap.parse_args(argv)

    cfg = PRESETS.get(args.preset) or get_config(args.preset, smoke=True)
    if args.fedcore:
        return train_fedcore_lm(cfg, args.rounds, args.steps, args.silos,
                                args.batch, args.seq, args.lr,
                                args.straggler_pct, args.seed)
    return train_centralized(cfg, args.steps, args.batch, args.seq, args.lr,
                             args.ckpt, args.log_every, args.seed)


if __name__ == "__main__":
    main()
