"""Sinks: where Recorder records go.

``InMemorySink`` keeps raw record dicts (tests and programmatic use),
``JSONLSink`` writes one JSON object per line (runs; numpy values are
converted at the serialization boundary only — the in-process records
are never mutated), and ``ConsoleSink`` renders the canonical ``round``
event as the exact text the runtimes' old ``verbose`` prints produced,
so ``verbose=True`` output is now capturable and testable through any
stream.
"""
from __future__ import annotations

import json
import sys
from typing import IO, List, Optional

import numpy as np


class Sink:
    """Sink interface: ``emit(record)`` per record, ``close()`` once."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class InMemorySink(Sink):
    """Collects raw record dicts in ``records``."""

    def __init__(self) -> None:
        self.records: List[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)


def _jsonify(obj):
    """json.dumps default hook: numpy scalars/arrays to plain python."""
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj).__name__}")


class JSONLSink(Sink):
    """One JSON object per line at ``path`` (created/truncated)."""

    def __init__(self, path: str) -> None:
        self.path = str(path)
        self._fh: Optional[IO[str]] = open(self.path, "w")

    def emit(self, record: dict) -> None:
        if self._fh is not None:
            self._fh.write(json.dumps(record, default=_jsonify) + "\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


# per-runtime round-event formats — byte-for-byte the text the old
# inline ``print()`` calls in server.py / events.py / fleet/batched.py
# produced, keyed by the canonical event's ``runtime`` field
def _fmt_sync(d: dict) -> str:
    return (f"[{d['label']}] round {d['round']:3d} "
            f"time {d['sim_round_time']:8.1f}s loss {d['train_loss']:.4f} "
            f"acc {d['test_acc']:.4f} (core {d['n_coreset']}, "
            f"drop {d['n_dropped']})")


def _fmt_async(d: dict) -> str:
    return (f"[{d['label']}] "
            f"update {d['applied']:4d} t={d['t_virtual']:9.1f}s "
            f"loss {d['train_loss']:.4f} acc {d['test_acc']:.4f} "
            f"(core {d['n_coreset']}, drop {d['n_dropped']})")


def _fmt_fleet(d: dict) -> str:
    return (f"[{d['label']}] round {d['round']:3d} "
            f"cohort {d['n_participants']:5d} "
            f"core {d['n_coreset']:5d} time {d['sim_round_time']:9.1f}s "
            f"loss {d['train_loss']:.4f} acc {d['test_acc']:.4f}")


def _fmt_async_fleet(d: dict) -> str:
    return (f"[{d['label']}] flush {d['round']:4d} "
            f"t={d['t_virtual']:9.1f}s merged {d['n_participants']:4d} "
            f"core {d['n_coreset']:4d} loss {d['train_loss']:.4f} "
            f"acc {d['test_acc']:.4f}")


ROUND_FORMATS = {"sync": _fmt_sync, "async": _fmt_async, "fleet": _fmt_fleet,
                 "async_fleet": _fmt_async_fleet}


class ConsoleSink(Sink):
    """Renders ``round`` events as the runtimes' historical verbose text."""

    def __init__(self, stream: Optional[IO[str]] = None) -> None:
        self._stream = stream

    def emit(self, record: dict) -> None:
        if record.get("kind") != "event" or record.get("name") != "round":
            return
        data = record.get("data", {})
        fmt = ROUND_FORMATS.get(data.get("runtime"))
        if fmt is None:
            return
        print(fmt(data), file=self._stream or sys.stdout)  # noqa: lint-noprint (the console sink IS the sanctioned print)
