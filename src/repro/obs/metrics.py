"""Counters, gauges, and histograms with one canonical snapshot shape.

Instruments are created on demand (``registry.counter("dispatches")``)
and are plain ``__slots__`` objects so the recording-on hot path is a
dict lookup plus an attribute add.  ``NULL_METRICS`` is the recording-off
twin: every accessor returns one shared no-op instrument, so runtimes can
instrument unconditionally without guarding on a recorder being active.

Snapshot shape (the ``data`` field of a ``kind="metrics"`` record)::

    {"counters":   {name: number},
     "gauges":     {name: number},
     "histograms": {name: {"count": int, "sum": float,
                           "min": float, "max": float,
                           "buckets": {label: int}}}}

Histograms bucket by powers of two by default (``le_2``, ``le_4``, ...)
— right for durations and byte counts spanning orders of magnitude — or
exactly by integer value with ``exact=True`` (right for staleness).
"""
from __future__ import annotations

import math
from typing import Dict


class Counter:
    """A monotonically increasing number (int or float)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """A last-value-wins number."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Count/sum/min/max plus bucket counts.

    ``exact=True`` buckets by exact integer value (small discrete
    domains: staleness, epochs); the default buckets by the smallest
    power of two >= the value, labelled ``le_<bound>``.
    """
    __slots__ = ("count", "total", "vmin", "vmax", "buckets", "exact")

    def __init__(self, exact: bool = False) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets: Dict[str, int] = {}
        self.exact = exact

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if self.exact:
            label = str(int(v))
        elif v <= 0.0:
            label = "le_0"
        else:
            label = f"le_{2.0 ** math.ceil(math.log2(v)):g}"
        self.buckets[label] = self.buckets.get(label, 0) + 1

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "buckets": dict(self.buckets),
        }


class MetricsRegistry:
    """Name -> instrument maps with on-demand creation."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, exact: bool = False) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(exact=exact)
        return h

    def snapshot(self) -> dict:
        return {
            "counters": {k: v.value for k, v in self._counters.items()},
            "gauges": {k: v.value for k, v in self._gauges.items()},
            "histograms": {k: v.snapshot()
                           for k, v in self._histograms.items()},
        }


class _NullInstrument:
    """Accepts inc/set/observe and drops them; reads as zero."""
    __slots__ = ()
    value = 0

    def inc(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class _NullMetrics:
    """Recording-off registry: every instrument is the shared no-op."""
    __slots__ = ()

    def counter(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, exact: bool = False) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}


NULL_METRICS = _NullMetrics()
