"""Unified observability layer shared by every FL runtime.

FedCore's claim is a *time* claim — an 8x wall-clock cut from eliminating
stragglers — so the repo needs one instrumentation layer that can answer
"where did round r spend its time, and which clients dragged it" for the
sync server, the async event engine, and all three fleet engines, from
one schema.  This package provides:

  * ``Recorder`` (``repro.obs.recorder``) — cheap structured events,
    monotonic-clock spans for the round phases (cohort build, local SGD,
    selection, coreset epochs, gather, aggregation, eval, ...), and a
    ``jax.profiler.TraceAnnotation`` bridge so device traces line up
    with our spans;
  * a metrics registry (``repro.obs.metrics``) — counters / gauges /
    histograms: dispatches, program-cache hits/misses/recompiles,
    per-client busy time, deadline-violation and staleness histograms,
    bytes moved per aggregation;
  * pluggable sinks (``repro.obs.sinks``) — in-memory (tests), JSONL
    file (runs), and a console sink that renders the canonical round
    event as the exact text the runtimes' old ``verbose`` prints
    produced;
  * the canonical record schema + validators (``repro.obs.schema``) —
    one "round" event shape emitted by every runtime so sync / async /
    loop / batched / sharded runs are directly comparable, rendered by
    ``benchmarks/report.py`` (``make report``).

Recording is ambient: runtimes call ``get_recorder()`` and get either
the recorder installed with ``use_recorder`` / ``set_recorder`` or a
zero-cost ``NullRecorder``.  Recording never touches RNG streams, event
ordering, or numerics — the determinism goldens in ``tests/test_obs.py``
assert byte-identical results with recording on vs off for every engine.
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.recorder import (NULL_RECORDER, NullRecorder, Recorder,
                                SCHEMA_VERSION, active_recorder,
                                get_recorder, set_recorder, use_recorder)
from repro.obs.sinks import ConsoleSink, InMemorySink, JSONLSink
from repro.obs.schema import read_jsonl, validate_record, validate_records

__all__ = [
    "Recorder", "NullRecorder", "NULL_RECORDER", "SCHEMA_VERSION",
    "get_recorder", "set_recorder", "use_recorder", "active_recorder",
    "MetricsRegistry", "ConsoleSink", "InMemorySink", "JSONLSink",
    "read_jsonl", "validate_record", "validate_records",
]
