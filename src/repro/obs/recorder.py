"""The Recorder: structured events + monotonic-clock spans + metrics.

Design constraints, in order:

1. **Determinism.**  Recording must never change what a runtime computes:
   the recorder reads ``time.perf_counter()`` and writes to sinks — it
   never touches RNG streams, never reorders events, never forces lazy
   arrays.  ``tests/test_obs.py`` holds byte-identical goldens per
   engine with recording on vs off.
2. **Zero cost when off.**  ``get_recorder()`` returns ``NULL_RECORDER``
   unless a recorder was installed; its spans are one shared no-op
   context manager (no clock reads) and its metrics are shared no-op
   instruments, so runtimes instrument unconditionally.
3. **Ambient, not threaded through.**  Runtimes call ``get_recorder()``
   instead of growing a ``recorder=`` parameter on every signature; the
   owner installs one with ``use_recorder(rec)`` / ``set_recorder``.

Spans nest via an explicit stack shared across ``scoped()`` views: each
emitted span record carries ``sid`` / ``parent`` / ``depth``, and both a
context-manager form (``with rec.span("eval"): ...``) and a manual form
(``span_begin`` / ``span_end``) exist — the async runtime needs manual
spans because its "round" is a record-window, not a lexical block.  With
``annotate=True`` every span also enters a ``jax.profiler.TraceAnnotation``
so device traces line up with our phase names.
"""
from __future__ import annotations

import contextvars
import time
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence

from repro.obs.metrics import NULL_METRICS, MetricsRegistry

try:  # profiler bridge is optional — never a hard dependency of recording
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax always present in this repo
    _TraceAnnotation = None

SCHEMA_VERSION = 1


class Span:
    """An open span; becomes one ``kind="span"`` record when ended.

    ``attrs`` stays mutable until the span ends, so call sites can stamp
    facts learned during the span (e.g. ``compile=True`` once the
    program cache is seen to have grown).
    """
    __slots__ = ("name", "sid", "parent", "depth", "t0", "attrs", "_ann")

    def __init__(self, name: str, sid: int, parent: Optional[int],
                 depth: int, t0: float, attrs: Dict[str, Any]) -> None:
        self.name = name
        self.sid = sid
        self.parent = parent
        self.depth = depth
        self.t0 = t0
        self.attrs = attrs
        self._ann = None


class _NullSpan:
    """Shared recording-off span: a no-op context manager."""
    __slots__ = ()

    @property
    def attrs(self) -> Dict[str, Any]:
        return {}  # fresh throwaway dict: writes are accepted and dropped

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _RunState:
    """Clock origin, sequence counter, span stack, and metrics — shared
    by a Recorder and every ``scoped()`` view of it."""
    __slots__ = ("clock", "t0", "seq", "stack", "metrics")

    def __init__(self, clock) -> None:
        self.clock = clock
        self.t0 = clock()
        self.seq = 0
        self.stack = []  # open Spans, innermost last
        self.metrics = MetricsRegistry()


class Recorder:
    """Emits run/span/event/metrics records to its sinks."""
    enabled = True

    def __init__(self, sinks: Sequence = (), annotate: bool = False,
                 clock=time.perf_counter, _state: Optional[_RunState] = None):
        self._sinks = tuple(sinks)
        self._annotate = bool(annotate) and _TraceAnnotation is not None
        self._state = _state if _state is not None else _RunState(clock)

    @property
    def metrics(self) -> MetricsRegistry:
        return self._state.metrics

    def scoped(self, *sinks) -> "Recorder":
        """A view sharing this recorder's clock/spans/metrics but also
        emitting to ``sinks`` (how ``verbose=True`` adds a console)."""
        if not sinks:
            return self
        return Recorder(self._sinks + tuple(sinks), annotate=self._annotate,
                        _state=self._state)

    # -- emission ----------------------------------------------------------
    def _now(self) -> float:
        s = self._state
        return s.clock() - s.t0

    def _next_seq(self) -> int:
        s = self._state
        s.seq += 1
        return s.seq

    def _emit(self, record: dict) -> None:
        for sink in self._sinks:
            sink.emit(record)

    def run_meta(self, **data) -> None:
        """One ``kind="run"`` record describing the run (runtime, engine,
        fleet size, seed, ...); every runtime emits this first."""
        self._emit({"v": SCHEMA_VERSION, "kind": "run",
                    "seq": self._next_seq(), "t": self._now(), "data": data})

    def event(self, name: str, **data) -> None:
        self._emit({"v": SCHEMA_VERSION, "kind": "event",
                    "seq": self._next_seq(), "t": self._now(),
                    "name": name, "data": data})

    # -- spans -------------------------------------------------------------
    def span_begin(self, name: str, **attrs) -> Span:
        st = self._state
        parent = st.stack[-1] if st.stack else None
        sp = Span(name, sid=self._next_seq(),
                  parent=parent.sid if parent is not None else None,
                  depth=len(st.stack), t0=self._now(), attrs=attrs)
        if self._annotate:
            sp._ann = _TraceAnnotation(name)
            sp._ann.__enter__()
        st.stack.append(sp)
        return sp

    def span_end(self, sp: Span) -> None:
        t1 = self._now()
        if sp._ann is not None:
            sp._ann.__exit__(None, None, None)
            sp._ann = None
        st = self._state
        # tolerate a mis-nested end by unwinding to the span being closed
        while st.stack and st.stack[-1] is not sp:
            st.stack.pop()
        if st.stack:
            st.stack.pop()
        self._emit({"v": SCHEMA_VERSION, "kind": "span",
                    "seq": self._next_seq(), "t": sp.t0, "name": sp.name,
                    "t0": sp.t0, "t1": t1, "dur": t1 - sp.t0,
                    "sid": sp.sid, "parent": sp.parent, "depth": sp.depth,
                    "attrs": dict(sp.attrs)})

    @contextmanager
    def span(self, name: str, **attrs):
        sp = self.span_begin(name, **attrs)
        try:
            yield sp
        finally:
            self.span_end(sp)

    # -- lifecycle ---------------------------------------------------------
    def flush_metrics(self) -> None:
        """Emit the current metrics snapshot as a ``kind="metrics"``
        record (also done by ``close``)."""
        self._emit({"v": SCHEMA_VERSION, "kind": "metrics",
                    "seq": self._next_seq(), "t": self._now(),
                    "data": self._state.metrics.snapshot()})

    def close(self) -> None:
        self.flush_metrics()
        for sink in self._sinks:
            sink.close()


class NullRecorder:
    """Recording off: every operation is a no-op, spans never read the
    clock, metrics are shared no-op instruments."""
    enabled = False
    metrics = NULL_METRICS

    def scoped(self, *sinks):
        if not sinks:
            return self
        return Recorder(sinks)

    def run_meta(self, **data) -> None:
        pass

    def event(self, name: str, **data) -> None:
        pass

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def span_begin(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def span_end(self, sp) -> None:
        pass

    def flush_metrics(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_RECORDER = NullRecorder()

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_recorder", default=None)


def get_recorder():
    """The ambient recorder, or ``NULL_RECORDER`` when none installed."""
    rec = _ACTIVE.get()
    return rec if rec is not None else NULL_RECORDER


def set_recorder(rec) -> None:
    """Install ``rec`` (or None to clear) as the ambient recorder."""
    _ACTIVE.set(rec)


@contextmanager
def use_recorder(rec):
    """Scoped install: the ambient recorder inside the ``with`` block."""
    token = _ACTIVE.set(rec)
    try:
        yield rec
    finally:
        _ACTIVE.reset(token)


def active_recorder(verbose: bool = False, stream=None):
    """What runtimes call once per run: the ambient recorder, with a
    console sink attached when ``verbose`` (replacing the old raw
    ``print()`` paths — same text, now capturable through any sink)."""
    rec = get_recorder()
    if verbose:
        from repro.obs.sinks import ConsoleSink
        rec = rec.scoped(ConsoleSink(stream))
    return rec
