"""The canonical record schema shared by every runtime, plus validators.

Envelope (every record): ``v`` (schema version), ``kind`` (one of
``run`` / ``span`` / ``event`` / ``metrics``), ``seq`` (emission order,
unique per run), ``t`` (seconds since the recorder's clock origin).

Kinds:

* ``run``    — ``data`` describes the run: at least ``runtime`` (one of
  ``sync`` / ``async`` / ``fleet`` / ``async_fleet``) and ``engine``.
* ``span``   — a closed phase span: ``name``, ``sid``, ``parent`` (sid
  or None), ``depth``, ``t0 <= t1``, ``dur``, free-form ``attrs``.
* ``event``  — a named point event with a ``data`` dict.  Two names are
  canonical and validated strictly so loop/batched/sharded/sync/async
  runs are directly comparable:

  - ``round``   — one per completed round/record-window, fields
    ``ROUND_REQUIRED`` below (identical across all five runtimes; a
    runtime may add extras like ``applied`` / ``t_virtual``).
  - ``clients`` — per-round straggler diagnostics: aligned ``cids`` /
    ``durations`` lists (sim seconds of busy time per participant).

* ``metrics`` — a MetricsRegistry snapshot (see ``repro.obs.metrics``).

``validate_records`` additionally checks run-level span invariants:
unique sids, parents that exist and strictly contain their children in
time, and depth consistency.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Sequence

KINDS = ("run", "span", "event", "metrics")

# canonical per-round schema — every runtime emits exactly these fields
# (plus free extras) so cross-runtime comparison needs no translation
ROUND_REQUIRED: Dict[str, tuple] = {
    "runtime": (str,),            # "sync" | "async" | "fleet" | "async_fleet"
    "engine": (str,),             # sync|async|loop|batched|sharded
    "label": (str,),              # console tag, e.g. "fedcore", "fleet/batched"
    "round": (int,),
    "n_participants": (int,),
    "n_dropped": (int,),
    "n_coreset": (int,),
    "n_violations": (int,),
    "sim_round_time": (int, float),
    "wall_time_s": (int, float),
    "train_loss": (int, float),
    "test_acc": (int, float),
    "test_loss": (int, float),
}

CLIENTS_REQUIRED: Dict[str, tuple] = {
    "round": (int,),
    "cids": (list,),
    "durations": (list,),
}

RUNTIMES = ("sync", "async", "fleet", "async_fleet")

# the phase-span vocabulary runtimes draw from (report orders columns by
# first appearance, so this is documentation + test reference, not a gate)
PHASES = ("cohort_build", "cohort_select", "local_update", "local_sgd",
          "grad_features", "distances", "selection", "coreset_group",
          "coreset_epochs", "dispatch", "gather", "aggregate",
          "trace_account", "eval", "buffer_fill", "dispatch_wave",
          "checkpoint")


def _fail(msg: str, record: dict) -> None:
    raise ValueError(f"obs schema: {msg}: {record!r}")


def _check_fields(data: dict, required: Dict[str, tuple],
                  record: dict, what: str) -> None:
    for field, types in required.items():
        if field not in data:
            _fail(f"{what} missing field {field!r}", record)
        v = data[field]
        # bool is an int subclass but never a sanctioned numeric here
        if not isinstance(v, types) or isinstance(v, bool):
            _fail(f"{what} field {field!r} has type "
                  f"{type(v).__name__}, wanted {types}", record)


def validate_record(record: dict) -> None:
    """Raise ValueError unless ``record`` matches the canonical schema."""
    if not isinstance(record, dict):
        raise ValueError(f"obs schema: record is not a dict: {record!r}")
    for field in ("v", "kind", "seq", "t"):
        if field not in record:
            _fail(f"missing envelope field {field!r}", record)
    kind = record["kind"]
    if kind not in KINDS:
        _fail(f"unknown kind {kind!r}", record)
    if not isinstance(record["seq"], int) or isinstance(record["seq"], bool):
        _fail("seq is not an int", record)
    if not isinstance(record["t"], (int, float)):
        _fail("t is not a number", record)

    if kind == "run":
        data = record.get("data")
        if not isinstance(data, dict):
            _fail("run record has no data dict", record)
        if data.get("runtime") not in RUNTIMES:
            _fail(f"run runtime {data.get('runtime')!r} not in {RUNTIMES}",
                  record)
        if not isinstance(data.get("engine"), str):
            _fail("run record missing engine", record)

    elif kind == "span":
        for field in ("name", "sid", "t0", "t1", "dur", "depth"):
            if field not in record:
                _fail(f"span missing {field!r}", record)
        if not isinstance(record.get("attrs"), dict):
            _fail("span attrs is not a dict", record)
        if record["t1"] < record["t0"]:
            _fail("span ends before it starts", record)
        if not math.isclose(record["dur"], record["t1"] - record["t0"],
                            rel_tol=1e-9, abs_tol=1e-9):
            _fail("span dur != t1 - t0", record)

    elif kind == "event":
        name = record.get("name")
        if not isinstance(name, str):
            _fail("event has no name", record)
        data = record.get("data")
        if not isinstance(data, dict):
            _fail("event has no data dict", record)
        if name == "round":
            _check_fields(data, ROUND_REQUIRED, record, "round event")
            if data["runtime"] not in RUNTIMES:
                _fail(f"round runtime {data['runtime']!r}", record)
        elif name == "clients":
            _check_fields(data, CLIENTS_REQUIRED, record, "clients event")
            if len(data["cids"]) != len(data["durations"]):
                _fail("clients cids/durations misaligned", record)

    elif kind == "metrics":
        data = record.get("data")
        if not isinstance(data, dict):
            _fail("metrics record has no data dict", record)
        for section in ("counters", "gauges", "histograms"):
            if not isinstance(data.get(section), dict):
                _fail(f"metrics record missing {section!r}", record)


def validate_records(records: Sequence[dict]) -> None:
    """Per-record validation plus run-level span-nesting invariants."""
    spans = []
    seqs = set()
    for record in records:
        validate_record(record)
        seq = record["seq"]
        if seq in seqs:
            _fail("duplicate seq", record)
        seqs.add(seq)
        if record["kind"] == "span":
            spans.append(record)

    by_sid = {}
    for sp in spans:
        if sp["sid"] in by_sid:
            _fail("duplicate span sid", sp)
        by_sid[sp["sid"]] = sp
    for sp in spans:
        parent = sp.get("parent")
        if parent is None:
            continue
        if parent not in by_sid:
            _fail(f"span parent sid {parent} never emitted", sp)
        pa = by_sid[parent]
        if sp["depth"] != pa["depth"] + 1:
            _fail("span depth is not parent depth + 1", sp)
        if sp["t0"] < pa["t0"] or sp["t1"] > pa["t1"]:
            _fail("span not contained in its parent's interval", sp)


def read_jsonl(path: str) -> List[dict]:
    """Load a JSONL run log (skipping blank lines)."""
    records = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records
