"""Pallas TPU kernel: pairwise Euclidean distance matrix.

The FedCore hot-spot (§4.2/§4.3): building the (m, m) gradient-distance
matrix that the k-medoids clustering consumes.  The paper computes this as
a per-pair loop on GPU/CPU; the TPU-native formulation is a tiled matmul —
``‖a − b‖² = ‖a‖² + ‖b‖² − 2 a·b`` — so the cross term runs on the MXU:

  grid = (m/bm, n/bn, d/bk); each (i, j) tile accumulates the −2·X Yᵀ
  cross-term over k-steps in an fp32 VMEM scratch, and on the last k-step
  fuses the ‖·‖² rank-1 epilogue, the clamp, and the sqrt.

Block sizes default to MXU-aligned 128/256/512 and are clipped to the
(padded) problem shape.  The wrapper in ``ops.py`` pads inputs to block
multiples with zero rows (distance contributions of zero-padding cancel in
the cross-term; padded rows are sliced off on return).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pairwise_kernel(x_ref, y_ref, xsq_ref, ysq_ref, out_ref, acc_ref, *,
                     squared: bool, n_k: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, bk)
    y = y_ref[...].astype(jnp.float32)           # (bn, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # x @ y.T

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        xsq = xsq_ref[...].astype(jnp.float32)   # (bm,)
        ysq = ysq_ref[...].astype(jnp.float32)   # (bn,)
        d = xsq[:, None] + ysq[None, :] - 2.0 * acc_ref[...]
        d = jnp.maximum(d, 0.0)
        if not squared:
            d = jnp.sqrt(d)
        out_ref[...] = d.astype(out_ref.dtype)


def pairwise_l2_pallas(x: jnp.ndarray, y: Optional[jnp.ndarray] = None, *,
                       squared: bool = False, block_m: int = 128,
                       block_n: int = 128, block_k: int = 512,
                       interpret: bool = False) -> jnp.ndarray:
    """x: (m, d); y: (n, d) or None (=x).  Returns (m, n) fp32 distances.

    Shapes must already be padded to block multiples (ops.py handles this).
    """
    y = x if y is None else y
    m, d = x.shape
    n = y.shape[0]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, d)
    assert m % block_m == 0 and n % block_n == 0 and d % block_k == 0
    n_k = d // block_k

    xsq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    ysq = jnp.sum(y.astype(jnp.float32) ** 2, axis=-1)

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_pairwise_kernel, squared=squared, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_m,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_n,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, y, xsq, ysq)


def _pairwise_batched_kernel(xi_ref, xj_ref, sqi_ref, sqj_ref, out_ref,
                             acc_ref, *, squared: bool, n_k: int):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = xi_ref[0].astype(jnp.float32)           # (bm, bk) rows
    xj = xj_ref[0].astype(jnp.float32)           # (bn, bk) cols
    acc_ref[...] += jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # xi @ xj.T for this client

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        sqi = sqi_ref[0].astype(jnp.float32)     # (bm,)
        sqj = sqj_ref[0].astype(jnp.float32)     # (bn,)
        d = sqi[:, None] + sqj[None, :] - 2.0 * acc_ref[...]
        d = jnp.maximum(d, 0.0)
        if not squared:
            d = jnp.sqrt(d)
        out_ref[0] = d.astype(out_ref.dtype)


def pairwise_l2_batched_pallas(x: jnp.ndarray, *, squared: bool = False,
                               block_m: int = 128, block_k: int = 512,
                               interpret: bool = False) -> jnp.ndarray:
    """Self-distance stacks for a client cohort: x (C, M, D) -> (C, M, M).

    The fleet engine's hot path (one distance matrix per client per round).
    Identical tiling to ``pairwise_l2_pallas`` with a leading client grid
    dimension — one (c, i, j) tile accumulates its −2·XXᵀ cross term over
    k-steps in VMEM and fuses the ‖·‖² epilogue on the last step.  Shapes
    must already be padded to block multiples (ops.py handles this).
    """
    c, m, d = x.shape
    block_m = min(block_m, m)
    block_k = min(block_k, d)
    assert m % block_m == 0 and d % block_k == 0
    n_k = d // block_k

    xsq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)     # (C, M)

    grid = (c, m // block_m, m // block_m, n_k)
    kernel = functools.partial(_pairwise_batched_kernel, squared=squared,
                               n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda b, i, j, k: (b, i, k)),
            pl.BlockSpec((1, block_m, block_k), lambda b, i, j, k: (b, j, k)),
            pl.BlockSpec((1, block_m), lambda b, i, j, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, i, j, k: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_m),
                               lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((c, m, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_m), jnp.float32)],
        interpret=interpret,
    )(x, x, xsq, xsq)
