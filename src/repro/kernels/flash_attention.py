"""Pallas TPU kernel: causal (optionally sliding-window) flash attention
with GQA head mapping.

Layout: q (B, Hq, S, hd), k/v (B, Hk, S, hd).  Grid = (B*Hq, S/bq, S/bk);
the kv dimension is the minor-most grid axis, which TPU iterates
sequentially per (bh, iq) cell, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across kv steps.  The GQA
mapping happens in the BlockSpec index_map (kv head = q head // q_per_kv) —
no materialized KV repeat.  Fully-masked kv blocks are skipped with
``pl.when`` (the causal/window block-level test), which on real hardware
skips both the HBM->VMEM copy epilogue compute; the last kv step writes
acc / l to the output tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, n_k: int,
                  causal: bool, window: Optional[int]):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level mask test: is any (q, k) pair in this tile visible?
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.asarray(True)
    if causal:
        live = live & (k_lo <= q_hi)
    if window is not None:
        live = live & (k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                            block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                            block_k), 1)
        ok = jnp.ones((block_q, block_k), bool)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False):
    """q: (B, Hq, S, hd); k/v: (B, Hk, S, hd).  S must divide the blocks."""
    b, hq, s, hd = q.shape
    hk = k.shape[1]
    assert hq % hk == 0
    qpk = hq // hk
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    n_k = s // block_k
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))

    grid = (b * hq, s // block_q, n_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bh, iq, ik: (bh // hq, bh % hq, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bh, iq, ik: (bh // hq, (bh % hq) // qpk,
                                             ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bh, iq, ik: (bh // hq, (bh % hq) // qpk,
                                             ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bh, iq, ik: (bh // hq, bh % hq, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
