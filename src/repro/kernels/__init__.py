"""Pallas TPU kernels for the perf-critical compute layers.

* ``pairwise_l2``      — the FedCore coreset distance matrix (MXU-tiled)
* ``flash_attention``  — GQA causal/windowed flash attention
* ``rmsnorm``          — fused RMSNorm

``ops`` holds the jit'd public wrappers (padding, backend selection,
interpret-mode on CPU); ``ref`` the pure-jnp oracles the tests assert
against.
"""
from repro.kernels import ops, ref  # noqa: F401
