"""Pallas TPU kernels for the perf-critical compute layers.

Map of which op each kernel fuses (module → ``ops`` wrapper → what the
single launch replaces):

* ``pairwise_l2`` → ``ops.pairwise_l2`` / ``ops.pairwise_l2_batched`` —
  the FedCore coreset distance matrix/stack: MXU-tiled ‖a‖²+‖b‖²−2ab
  with the norm epilogue, clamp, sqrt, and (``zero_diag``) diagonal
  fix-up fused into the cross-term accumulation; the batched variant
  carries a leading client grid dim (one cohort group = one launch).
* ``kmedoids_pallas.build_cost_pallas`` → ``ops.kmedoids_build_cost`` —
  the k-medoids BUILD greedy add-cost Σᵢ min(d_near, D[i, j])·vfᵢ,
  streamed tile-by-tile instead of materializing the (C, M, M)
  ``minimum`` tensor each greedy step.
* ``kmedoids_pallas.delta_sweep_pallas`` → ``ops.kmedoids_delta_sweep``
  — one FasterPAM swap sweep's A_j and B_{j,l} reductions in a single
  pass over D (replacing the 3+-pass ``minimum``/``one_hot``/``einsum``
  chain), with the per-tile one-hot segment matmul on the MXU.
* ``kmedoids_pallas.build_cost_from_feats_pallas`` →
  ``ops.kmedoids_build_cost_from_feats`` — the **distance-free** BUILD
  add-cost: pairwise distances recomputed on the fly from the (C, M, F)
  feature stack, flash-attention-style (F-dim tiled into a VMEM dot
  accumulator, distance epilogue at the last F-step), so the (C, M, M)
  tensor D never exists.  Peak selection memory drops from O(C·M²) to
  O(C·M·F) — per-client M in the thousands instead of hundreds —
  with padded lanes masked to +1e30 in-kernel so zero-padded rows
  (mutually at distance 0) can never win a medoid election.
* ``kmedoids_pallas.delta_sweep_from_feats_pallas`` →
  ``ops.kmedoids_delta_sweep_from_feats`` — the distance-free FasterPAM
  Δ-sweep: same on-the-fly distance tiles feeding the A_j / B_{j,l}
  fold, same single launch per sweep.
* ``flash_attention`` → ``ops.flash_attention`` — GQA causal/windowed
  flash attention (softmax streamed, scores never materialized).
* ``rmsnorm`` → ``ops.rmsnorm`` — fused RMSNorm over the last axis.

``ops`` holds the jit'd public wrappers (padding, backend selection via
the tri-state ``resolve_use_kernel``, interpret-mode on CPU so CI covers
every kernel); ``ref`` the pure-jnp oracles the tests assert against —
and the identical-math fallbacks the wrappers run where the kernels
don't pay (the fused selection path calls the same functions either
way).
"""
from repro.kernels import ops, ref  # noqa: F401
