"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, GQA/odd shapes, backend selection
(interpret mode on CPU so the whole framework runs in this container;
compiled kernels on real TPU), and expose a jnp fallback for shapes the
kernels don't support.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.kmedoids_pallas import (build_cost_from_feats_pallas,
                                           build_cost_pallas,
                                           delta_sweep_from_feats_pallas,
                                           delta_sweep_pallas)
from repro.kernels.pairwise_l2 import (pairwise_l2_batched_pallas,
                                       pairwise_l2_pallas)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_use_kernel(use_kernel: Optional[bool]) -> bool:
    """Resolve the tri-state kernel switch used across the selection path.

    ``True``/``False`` force the Pallas kernels on/off; ``None`` (auto)
    enables them on backends where they compile natively (TPU) and falls
    back to the identical-math jnp formulations elsewhere — interpret
    mode keeps CI coverage, but on CPU the fused jnp path is the fast
    one.  Resolve *before* any jit boundary so auto and its resolved
    value share one compilation cache entry.
    """
    return _on_tpu() if use_kernel is None else bool(use_kernel)


def zero_self_diag(d: jnp.ndarray) -> jnp.ndarray:
    """Exact zeros on the self-distance diagonal of (..., M, M) stacks.

    ``‖a‖² + ‖b‖² − 2ab`` cancels imperfectly in float32, leaving tiny
    nonzeros (or NaN-adjacent negatives pre-clamp) on the diagonal; every
    self-distance consumer (k-medoids BUILD/SWAP) needs literal zeros.
    This helper is the single owner of that fix-up — the pairwise
    wrappers apply it under ``zero_diag=True`` rather than each caller
    re-deriving it.
    """
    m = d.shape[-1]
    return d * (1.0 - jnp.eye(m, dtype=d.dtype))


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _pow2_block(n: int, cap: int, shrink: bool, floor: int = 8) -> int:
    """Tile size for a dim of size ``n``: the next power of two, clipped to
    [floor, cap].  With ``shrink`` (interpret mode only), shapes smaller
    than the default MXU tile get a tile sized to the problem instead of
    padding up to the full block — for the fleet engine's small cohort
    groups (M = 32/64) this cuts the padded distance work by up to 16x.
    Compiled TPU kernels keep the MXU-aligned defaults: sub-(8, 128)
    blocks fight Mosaic's float32 tiling for no bandwidth win there."""
    if not shrink:
        return cap
    p = 1 << max(int(n) - 1, 0).bit_length()
    return max(floor, min(cap, p))


@functools.partial(jax.jit, static_argnames=("squared", "zero_diag",
                                             "block_m", "block_n",
                                             "block_k", "interpret"))
def pairwise_l2(x, y=None, *, squared: bool = False, zero_diag: bool = False,
                block_m: int = 128, block_n: int = 128, block_k: int = 512,
                interpret: Optional[bool] = None):
    """Pairwise Euclidean distances via the MXU-tiled kernel.

    Zero-row padding is exact for the cross term; padded rows/cols are
    sliced off before returning.  ``zero_diag`` (self-mode only) pins the
    self-distance diagonal to exact zeros for k-medoids consumers.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    self_mode = y is None
    y = x if y is None else y
    block_m = _pow2_block(x.shape[0], block_m, shrink=interpret)
    block_n = _pow2_block(y.shape[0], block_n, shrink=interpret)
    xp, m = _pad_to(x, 0, block_m)
    yp, n = _pad_to(y, 0, block_n)
    xp, d = _pad_to(xp, 1, 128)
    yp, _ = _pad_to(yp, 1, 128)
    bk = min(block_k, xp.shape[1])
    while xp.shape[1] % bk:
        bk //= 2
    out = pairwise_l2_pallas(xp, None if self_mode and xp.shape == yp.shape
                             else yp, squared=squared, block_m=block_m,
                             block_n=block_n, block_k=bk,
                             interpret=interpret)
    out = out[:m, :n]
    return zero_self_diag(out) if zero_diag and self_mode else out


@functools.partial(jax.jit, static_argnames=("squared", "use_kernel",
                                             "zero_diag", "block_m",
                                             "block_k", "interpret"))
def pairwise_l2_batched(x, *, squared: bool = False, use_kernel: bool = True,
                        zero_diag: bool = False, block_m: int = 128,
                        block_k: int = 512,
                        interpret: Optional[bool] = None):
    """Per-client self-distance stacks: x (C, M, D) -> (C, M, M).

    The fleet engine's batched coreset-selection front end.  Pads M and D
    to block multiples (zero rows are exact for the cross term, and padded
    rows/cols are sliced off before returning) and dispatches to the
    batched Pallas kernel; ``use_kernel=False`` is the identical-math jnp
    einsum formulation for backends/shapes the kernel doesn't cover.
    ``zero_diag`` pins each client's self-distance diagonal to exact
    zeros (the k-medoids contract).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if not use_kernel:
        out = jax.vmap(lambda xi: ref.pairwise_l2_ref(xi, squared=squared)
                       )(x)
        return zero_self_diag(out) if zero_diag else out
    block_m = _pow2_block(x.shape[1], block_m, shrink=interpret)
    xp, m = _pad_to(x, 1, block_m)
    xp, _ = _pad_to(xp, 2, 128)
    bk = min(block_k, xp.shape[2])
    while xp.shape[2] % bk:
        bk //= 2
    out = pairwise_l2_batched_pallas(xp, squared=squared, block_m=block_m,
                                     block_k=bk, interpret=interpret)
    out = out[:, :m, :m]
    return zero_self_diag(out) if zero_diag else out


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 256,
                    block_k: int = 256, interpret: Optional[bool] = None):
    """q (B,Hq,S,hd), k/v (B,Hk,S,hd) -> (B,Hq,S,hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    s = q.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, s)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, block_q=bq, block_k=bk,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_m",
                                             "interpret"))
def kmedoids_build_cost(D, d_near, vf, *, use_kernel: bool = True,
                        block_m: int = 128,
                        interpret: Optional[bool] = None):
    """Fused BUILD add-cost: D (C, M, M), d_near/vf (C, M) -> (C, M).

    One tiled pass over the distance stack per greedy add instead of a
    materialized (C, M, M) ``minimum`` tensor.  ``use_kernel=False`` is
    the identical-math jnp formulation (``ref.kmedoids_build_cost_ref``).
    Padded rows/cols (to the block multiple) carry vf = 0 so they add
    exactly nothing; padded cost columns are sliced off.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if not use_kernel:
        return ref.kmedoids_build_cost_ref(D, d_near, vf)
    m = D.shape[1]
    block_m = _pow2_block(m, block_m, shrink=interpret)
    Dp, _ = _pad_to(D, 1, block_m)
    Dp, _ = _pad_to(Dp, 2, block_m)
    dnp, _ = _pad_to(d_near, 1, block_m)
    vfp, _ = _pad_to(vf, 1, block_m)
    out = build_cost_pallas(Dp, dnp, vfp, block_m=block_m,
                            interpret=interpret)
    return out[:, :m]


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_m",
                                             "interpret"))
def kmedoids_delta_sweep(D, d1, d2, vf, n_onehot, *, use_kernel: bool = True,
                         block_m: int = 128,
                         interpret: Optional[bool] = None):
    """Fused FasterPAM Δ-sweep reductions: one pass over D per sweep.

    D (C, M, M); d1/d2/vf (C, M); n_onehot (C, M, k).  Returns
    (A (C, M), B (C, M, k)) with Δ(j, l) = A[:, j] + B[:, j, l] — see
    ``ref.kmedoids_delta_sweep_ref`` for the math, which is also the
    ``use_kernel=False`` fallback.  M pads to the block multiple
    (vf = 0 rows contribute nothing), k pads to a lane-aligned width
    with zero one-hot mass (extra B columns are exactly 0, sliced off).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if not use_kernel:
        return ref.kmedoids_delta_sweep_ref(D, d1, d2, vf, n_onehot)
    m, k = D.shape[1], n_onehot.shape[-1]
    block_m = _pow2_block(m, block_m, shrink=interpret)
    k_pad = _pow2_block(k, 128, shrink=True) if interpret else -(-k // 128
                                                                 ) * 128
    Dp, _ = _pad_to(D, 1, block_m)
    Dp, _ = _pad_to(Dp, 2, block_m)
    d1p, _ = _pad_to(d1, 1, block_m)
    d2p, _ = _pad_to(d2, 1, block_m)
    vfp, _ = _pad_to(vf, 1, block_m)
    ohp, _ = _pad_to(n_onehot, 1, block_m)
    ohp, _ = _pad_to(ohp, 2, k_pad)
    A, B = delta_sweep_pallas(Dp, d1p, d2p, vfp, ohp, block_m=block_m,
                              interpret=interpret)
    return A[:, :m], B[:, :m, :k]


_BIG = 1e30      # candidate mask for padded lanes (matches core.kmedoids.BIG)


def _feat_blocks(m: int, f: int, block_m: int, block_k: int,
                 interpret: bool):
    """(block_m, block_k, f_multiple) for the feature-tiled kernels.

    The stack-path wrappers shrink block_m to the problem in interpret
    mode but always pad F up to 128, so a tiny cohort group (M = 32,
    F = 16) paid pow2 padding waste twice — once in M, once in F.  Here
    interpret mode sizes BOTH tiles to the problem (pow2, floor 8) and
    pads F only up to the shrunk tile; compiled TPU kernels keep the
    lane-aligned 128-multiple on F (Mosaic's float32 lane requirement)
    and the MXU-sized block_m.
    """
    bm = _pow2_block(m, block_m, shrink=interpret)
    if interpret:
        bk = _pow2_block(f, block_k, shrink=True)
        return bm, bk, bk
    fp = -(-f // 128) * 128
    bk = min(block_k, fp)
    while fp % bk:
        bk //= 2
    return bm, bk, 128


def _feats_dist_chunk(xf, sq, j0, chunk):
    """(C, M, chunk) distance slab for candidate columns [j0, j0+chunk).

    Exact-zero diagonal pinned via global row/col index comparison (the
    chunked analogue of ``zero_self_diag``).
    """
    xj = jax.lax.dynamic_slice_in_dim(xf, j0, chunk, axis=1)
    sqj = jax.lax.dynamic_slice_in_dim(sq, j0, chunk, axis=1)
    d2 = (sq[..., :, None] + sqj[..., None, :]
          - 2.0 * jnp.einsum("cif,cjf->cij", xf, xj))
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    rows = jnp.arange(xf.shape[1])[None, :, None]
    cols = (j0 + jnp.arange(chunk))[None, None, :]
    return jnp.where(rows == cols, 0.0, d)


def _feats_prep_chunked(x, chunk: int):
    """Pad M to a chunk multiple and precompute fp32 features + sq norms."""
    m = x.shape[1]
    chunk = min(chunk, _pow2_block(m, chunk, shrink=True))
    xp, _ = _pad_to(x, 1, chunk)
    xf = xp.astype(jnp.float32)
    sq = jnp.sum(xf * xf, axis=-1)
    starts = jnp.arange(0, xp.shape[1], chunk)
    return xf, sq, starts, chunk, m


def _build_cost_from_feats_jnp(x, d_near, vf, *, chunk: int):
    """O(C·M·chunk) jnp fallback: lax.map over candidate-column chunks."""
    xf, sq, starts, chunk, m = _feats_prep_chunked(x, chunk)
    vfp, _ = _pad_to(vf, 1, chunk)
    dnp, _ = _pad_to(d_near, 1, chunk)

    def body(j0):
        d = _feats_dist_chunk(xf, sq, j0, chunk)
        cost = jnp.sum(jnp.minimum(dnp[..., None], d)
                       * vfp[..., None], axis=-2)
        vfj = jax.lax.dynamic_slice_in_dim(vfp, j0, chunk, axis=1)
        return jnp.where(vfj > 0.0, cost, _BIG)

    out = jax.lax.map(body, starts)               # (n_chunks, C, chunk)
    return jnp.moveaxis(out, 0, 1).reshape(x.shape[0], -1)[:, :m]


def _delta_sweep_from_feats_jnp(x, d1, d2, vf, n_onehot, *, chunk: int):
    """O(C·M·chunk) jnp fallback for the Δ-sweep reductions."""
    xf, sq, starts, chunk, m = _feats_prep_chunked(x, chunk)
    vfp, _ = _pad_to(vf, 1, chunk)
    ohp, _ = _pad_to(n_onehot, 1, chunk)
    d1p, _ = _pad_to(d1, 1, chunk)
    d2p, _ = _pad_to(d2, 1, chunk)
    d1e = d1p[..., None]
    d2e = d2p[..., None]
    vfe = vfp[..., None]

    def body(j0):
        d = _feats_dist_chunk(xf, sq, j0, chunk)
        shift = (jnp.minimum(d, d1e) - d1e) * vfe
        contrib = (jnp.clip(d, d1e, d2e) - d1e) * vfe
        a = jnp.sum(shift, axis=-2)               # (C, chunk)
        b = jnp.einsum("cij,cil->cjl", contrib, ohp)
        vfj = jax.lax.dynamic_slice_in_dim(vfp, j0, chunk, axis=1)
        return jnp.where(vfj > 0.0, a, _BIG), b

    A, B = jax.lax.map(body, starts)
    c = x.shape[0]
    A = jnp.moveaxis(A, 0, 1).reshape(c, -1)[:, :m]
    B = jnp.moveaxis(B, 0, 1).reshape(c, -1, n_onehot.shape[-1])[:, :m]
    return A, B


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_m",
                                             "block_k", "chunk",
                                             "interpret"))
def kmedoids_build_cost_from_feats(x, d_near, vf, *, use_kernel: bool = True,
                                   block_m: int = 128, block_k: int = 128,
                                   chunk: int = 256,
                                   interpret: Optional[bool] = None):
    """Distance-free BUILD add-cost: x (C, M, F), d_near/vf (C, M) -> (C, M).

    Same reduction as :func:`kmedoids_build_cost` but the (C, M, M)
    distance stack never exists — the Pallas kernel rebuilds each
    distance tile from F-tiled cross terms (O(C·M·F) memory), and the
    ``use_kernel=False`` fallback streams O(C·M·chunk) column slabs via
    ``lax.map``.  Padded candidate columns (vf = 0) return +BIG so they
    can never win the greedy argmin; padded rows contribute nothing.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if not use_kernel:
        return _build_cost_from_feats_jnp(x, d_near, vf, chunk=chunk)
    m = x.shape[1]
    bm, bk, fmul = _feat_blocks(m, x.shape[2], block_m, block_k, interpret)
    xp, _ = _pad_to(x, 1, bm)
    xp, _ = _pad_to(xp, 2, fmul)
    dnp, _ = _pad_to(d_near, 1, bm)
    vfp, _ = _pad_to(vf, 1, bm)
    out = build_cost_from_feats_pallas(xp, dnp, vfp, block_m=bm, block_k=bk,
                                       interpret=interpret)
    return out[:, :m]


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_m",
                                             "block_k", "chunk",
                                             "interpret"))
def kmedoids_delta_sweep_from_feats(x, d1, d2, vf, n_onehot, *,
                                    use_kernel: bool = True,
                                    block_m: int = 128, block_k: int = 128,
                                    chunk: int = 256,
                                    interpret: Optional[bool] = None):
    """Distance-free FasterPAM Δ-sweep: x (C, M, F) in, (A, B) out.

    Same (A, B) split as :func:`kmedoids_delta_sweep` with D rebuilt on
    the fly per tile; A carries +BIG at padded candidates (vf = 0) so a
    zero-padded feature row can never tie-win a swap over a valid point
    (zero rows are mutually at distance 0 — the election bug this
    masking closes).  ``use_kernel=False`` streams column slabs.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if not use_kernel:
        return _delta_sweep_from_feats_jnp(x, d1, d2, vf, n_onehot,
                                           chunk=chunk)
    m, k = x.shape[1], n_onehot.shape[-1]
    bm, bk, fmul = _feat_blocks(m, x.shape[2], block_m, block_k, interpret)
    k_pad = _pow2_block(k, 128, shrink=True) if interpret else -(-k // 128
                                                                 ) * 128
    xp, _ = _pad_to(x, 1, bm)
    xp, _ = _pad_to(xp, 2, fmul)
    d1p, _ = _pad_to(d1, 1, bm)
    d2p, _ = _pad_to(d2, 1, bm)
    vfp, _ = _pad_to(vf, 1, bm)
    ohp, _ = _pad_to(n_onehot, 1, bm)
    ohp, _ = _pad_to(ohp, 2, k_pad)
    A, B = delta_sweep_from_feats_pallas(xp, d1p, d2p, vfp, ohp, block_m=bm,
                                         block_k=bk, interpret=interpret)
    return A[:, :m], B[:, :m, :k]


@functools.partial(jax.jit, static_argnames=("eps", "block_m", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_m: int = 256,
            interpret: Optional[bool] = None):
    """Fused RMSNorm over the last axis; leading axes are flattened."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    xp, m = _pad_to(x2, 0, min(block_m, max(1, x2.shape[0])))
    bm = min(block_m, xp.shape[0])
    while xp.shape[0] % bm:
        bm //= 2
    out = rmsnorm_pallas(xp, scale, eps=eps, block_m=bm, interpret=interpret)
    return out[:m].reshape(shape)
