"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, GQA/odd shapes, backend selection
(interpret mode on CPU so the whole framework runs in this container;
compiled kernels on real TPU), and expose a jnp fallback for shapes the
kernels don't support.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pairwise_l2 import (pairwise_l2_batched_pallas,
                                       pairwise_l2_pallas)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _pow2_block(n: int, cap: int, shrink: bool, floor: int = 8) -> int:
    """Tile size for a dim of size ``n``: the next power of two, clipped to
    [floor, cap].  With ``shrink`` (interpret mode only), shapes smaller
    than the default MXU tile get a tile sized to the problem instead of
    padding up to the full block — for the fleet engine's small cohort
    groups (M = 32/64) this cuts the padded distance work by up to 16x.
    Compiled TPU kernels keep the MXU-aligned defaults: sub-(8, 128)
    blocks fight Mosaic's float32 tiling for no bandwidth win there."""
    if not shrink:
        return cap
    p = 1 << max(int(n) - 1, 0).bit_length()
    return max(floor, min(cap, p))


@functools.partial(jax.jit, static_argnames=("squared", "block_m", "block_n",
                                             "block_k", "interpret"))
def pairwise_l2(x, y=None, *, squared: bool = False, block_m: int = 128,
                block_n: int = 128, block_k: int = 512,
                interpret: Optional[bool] = None):
    """Pairwise Euclidean distances via the MXU-tiled kernel.

    Zero-row padding is exact for the cross term; padded rows/cols are
    sliced off before returning.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    self_mode = y is None
    y = x if y is None else y
    block_m = _pow2_block(x.shape[0], block_m, shrink=interpret)
    block_n = _pow2_block(y.shape[0], block_n, shrink=interpret)
    xp, m = _pad_to(x, 0, block_m)
    yp, n = _pad_to(y, 0, block_n)
    xp, d = _pad_to(xp, 1, 128)
    yp, _ = _pad_to(yp, 1, 128)
    bk = min(block_k, xp.shape[1])
    while xp.shape[1] % bk:
        bk //= 2
    out = pairwise_l2_pallas(xp, None if self_mode and xp.shape == yp.shape
                             else yp, squared=squared, block_m=block_m,
                             block_n=block_n, block_k=bk,
                             interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("squared", "use_kernel",
                                             "block_m", "block_k",
                                             "interpret"))
def pairwise_l2_batched(x, *, squared: bool = False, use_kernel: bool = True,
                        block_m: int = 128, block_k: int = 512,
                        interpret: Optional[bool] = None):
    """Per-client self-distance stacks: x (C, M, D) -> (C, M, M).

    The fleet engine's batched coreset-selection front end.  Pads M and D
    to block multiples (zero rows are exact for the cross term, and padded
    rows/cols are sliced off before returning) and dispatches to the
    batched Pallas kernel; ``use_kernel=False`` is the identical-math jnp
    einsum formulation for backends/shapes the kernel doesn't cover.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if not use_kernel:
        return jax.vmap(lambda xi: ref.pairwise_l2_ref(xi, squared=squared)
                        )(x)
    block_m = _pow2_block(x.shape[1], block_m, shrink=interpret)
    xp, m = _pad_to(x, 1, block_m)
    xp, _ = _pad_to(xp, 2, 128)
    bk = min(block_k, xp.shape[2])
    while xp.shape[2] % bk:
        bk //= 2
    out = pairwise_l2_batched_pallas(xp, squared=squared, block_m=block_m,
                                     block_k=bk, interpret=interpret)
    return out[:, :m, :m]


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 256,
                    block_k: int = 256, interpret: Optional[bool] = None):
    """q (B,Hq,S,hd), k/v (B,Hk,S,hd) -> (B,Hq,S,hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    s = q.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, s)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, block_q=bq, block_k=bk,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_m", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_m: int = 256,
            interpret: Optional[bool] = None):
    """Fused RMSNorm over the last axis; leading axes are flattened."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    xp, m = _pad_to(x2, 0, min(block_m, max(1, x2.shape[0])))
    bm = min(block_m, xp.shape[0])
    while xp.shape[0] % bm:
        bm //= 2
    out = rmsnorm_pallas(xp, scale, eps=eps, block_m=bm, interpret=interpret)
    return out[:m].reshape(shape)
