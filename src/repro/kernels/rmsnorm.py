"""Pallas TPU kernel: fused RMSNorm (row-tiled, fp32 reduction in VMEM).

Small but ubiquitous: every layer of every assigned architecture calls it
twice per token.  Fusing the square-mean reduction with the scale multiply
keeps the activation in VMEM for a single HBM round-trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (bm, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5,
                   block_m: int = 256, interpret: bool = False):
    """x: (m, d) — rows must divide block_m (ops.py pads)."""
    m, d = x.shape
    block_m = min(block_m, m)
    assert m % block_m == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, scale)
