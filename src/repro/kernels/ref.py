"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition the kernel must reproduce;
tests sweep shapes/dtypes and assert allclose between kernel (interpret
mode on CPU) and these references.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def pairwise_l2_ref(x: jnp.ndarray, y: Optional[jnp.ndarray] = None, *,
                    squared: bool = False) -> jnp.ndarray:
    """(m, d), (n, d) -> (m, n) Euclidean distances, fp32 accumulation."""
    y = x if y is None else y
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    sq = (jnp.sum(xf * xf, axis=-1)[:, None]
          + jnp.sum(yf * yf, axis=-1)[None, :] - 2.0 * (xf @ yf.T))
    sq = jnp.maximum(sq, 0.0)
    return sq if squared else jnp.sqrt(sq)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, S, hd); k/v: (B, Hk, S, hd) -> (B, Hq, S, hd)."""
    b, hq, s, hd = q.shape
    hk = k.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(
        jnp.asarray(hd, jnp.float32))
    kr = jnp.repeat(k, hq // hk, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, hq // hk, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    logits = jnp.where(ok, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
