"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition the kernel must reproduce;
tests sweep shapes/dtypes and assert allclose between kernel (interpret
mode on CPU) and these references.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def pairwise_l2_ref(x: jnp.ndarray, y: Optional[jnp.ndarray] = None, *,
                    squared: bool = False) -> jnp.ndarray:
    """(m, d), (n, d) -> (m, n) Euclidean distances, fp32 accumulation."""
    y = x if y is None else y
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    sq = (jnp.sum(xf * xf, axis=-1)[:, None]
          + jnp.sum(yf * yf, axis=-1)[None, :] - 2.0 * (xf @ yf.T))
    sq = jnp.maximum(sq, 0.0)
    return sq if squared else jnp.sqrt(sq)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, S, hd); k/v: (B, Hk, S, hd) -> (B, Hq, S, hd)."""
    b, hq, s, hd = q.shape
    hk = k.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(
        jnp.asarray(hd, jnp.float32))
    kr = jnp.repeat(k, hq // hk, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, hq // hk, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    logits = jnp.where(ok, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def kmedoids_build_cost_ref(D: jnp.ndarray, d_near: jnp.ndarray,
                            vf: jnp.ndarray) -> jnp.ndarray:
    """Greedy BUILD add-cost over a masked distance stack.

    D (..., M, M); d_near/vf (..., M).  Returns
    cost[..., j] = Σ_i min(d_near_i, D_ij)·vf_i — the cost of the point
    set after adding candidate j to the current medoids (``d_near`` is
    each point's distance to its nearest already-chosen medoid; pass
    +BIG for the first pick so cost reduces to the plain column sum).
    """
    add = jnp.minimum(d_near[..., None], D) * vf[..., None]
    return jnp.sum(add, axis=-2)


def kmedoids_delta_sweep_ref(D: jnp.ndarray, d1: jnp.ndarray,
                             d2: jnp.ndarray, vf: jnp.ndarray,
                             n_onehot: jnp.ndarray):
    """FasterPAM swap-sweep reductions (the Δ(j, l) = A_j + B_{j,l} split).

    D (..., M, M); d1/d2/vf (..., M); n_onehot (..., M, K) one-hot of each
    point's nearest-medoid slot.  Returns (A (..., M), B (..., M, K)):

        A[j]    = Σ_i (min(D_ij, d1_i) − d1_i) · vf_i
        B[j, l] = Σ_{i: n(i)=l} (clip(D_ij, d1_i, d2_i) − d1_i) · vf_i

    ``clip(D, d1, d2) − d1`` is the case-collapsed form of the textbook
    ``min(D, d2) − d1 − min(D − d1, 0)`` (bitwise equal for d1 ≤ d2):
    one elementwise pass instead of three.
    """
    d1e = d1[..., None]
    shift = (jnp.minimum(D, d1e) - d1e) * vf[..., None]
    contrib = (jnp.clip(D, d1e, d2[..., None]) - d1e) * vf[..., None]
    A = jnp.sum(shift, axis=-2)
    B = jnp.einsum("...ij,...il->...jl", contrib, n_onehot)
    return A, B


# ---------------------------------------------------------------------------
# distance-free oracles: these DO materialize D — that is the point.
# The parity gate is "fused feature-tiled kernel == materialize-then-reduce",
# exactly as PR 4 gated the Δ-sweep against the unfused stack.
# ---------------------------------------------------------------------------

_BIG = 1e30


def _pairwise_from_feats(x: jnp.ndarray) -> jnp.ndarray:
    """(..., M, F) -> (..., M, M) L2 stack with an exact-zero diagonal."""
    xf = x.astype(jnp.float32)
    sq = jnp.sum(xf * xf, axis=-1)
    d2 = (sq[..., :, None] + sq[..., None, :]
          - 2.0 * jnp.einsum("...if,...jf->...ij", xf, xf))
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    m = x.shape[-2]
    eye = jnp.eye(m, dtype=bool)
    return jnp.where(eye, 0.0, d)


def kmedoids_build_cost_from_feats_ref(x: jnp.ndarray, d_near: jnp.ndarray,
                                       vf: jnp.ndarray) -> jnp.ndarray:
    """Materializing oracle for ``build_cost_from_feats_pallas``.

    x (..., M, F); d_near/vf (..., M).  Builds the full distance stack,
    runs the BUILD reduction, then masks padded candidate columns
    (vf_j = 0) to +BIG — the same +inf election guard the fused kernel
    applies in its epilogue so a zero-padded feature row can never
    tie-win over a valid point.
    """
    D = _pairwise_from_feats(x)
    cost = kmedoids_build_cost_ref(D, d_near, vf)
    return jnp.where(vf > 0.0, cost, _BIG)


def kmedoids_delta_sweep_from_feats_ref(x: jnp.ndarray, d1: jnp.ndarray,
                                        d2: jnp.ndarray, vf: jnp.ndarray,
                                        n_onehot: jnp.ndarray):
    """Materializing oracle for ``delta_sweep_from_feats_pallas``.

    Same (A, B) split as :func:`kmedoids_delta_sweep_ref`, computed from
    the (..., M, F) feature stack by materializing D first, with
    A[..., j] = +BIG for padded candidates (vf_j = 0).
    """
    D = _pairwise_from_feats(x)
    A, B = kmedoids_delta_sweep_ref(D, d1, d2, vf, n_onehot)
    return jnp.where(vf > 0.0, A, _BIG), B
