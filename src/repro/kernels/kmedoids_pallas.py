"""Pallas TPU kernels for the fused k-medoids selection fast path.

The FedCore selection phase (Eq. 5) spends its time in two dense
reductions over the per-client distance stack D (C, M, M):

* **BUILD** — each greedy add evaluates every candidate j's add-cost
      cost[c, j] = Σ_i min(d_near[c, i], D[c, i, j]) · vf[c, i]
  The jnp formulation materializes the (C, M, M) ``minimum`` tensor per
  step; ``build_cost_pallas`` streams D tile-by-tile and keeps only a
  (1, bm) accumulator in VMEM.

* **Δ-sweep** — one FasterPAM swap sweep needs (Schubert & Rousseeuw
  2021, see ``repro.core.kmedoids``):
      A[c, j]    = Σ_i (min(D_ij, d1_i) − d1_i) · vf_i
      B[c, j, l] = Σ_{i: n(i)=l} (clip(D_ij, d1_i, d2_i) − d1_i) · vf_i
  The jnp chain makes 3+ full O(M²) HBM passes per sweep (shift tensor,
  contrib tensor, one-hot einsum).  ``delta_sweep_pallas`` computes both
  reductions in a **single tiled pass** over D: each (c, j, i) tile
  builds shift/contrib in registers, folds shift into a row-sum
  accumulator and contrib into a (bm, K) MXU matmul against the
  nearest-medoid one-hot — the memory traffic finally matches the math.

* **Distance-free variants** — ``delta_sweep_from_feats_pallas`` and
  ``build_cost_from_feats_pallas`` compute the SAME reductions **without
  D ever existing**: distances are rebuilt on the fly from the (C, M, F)
  gradient-feature stack inside each (i, j) tile, flash-attention-style
  (``kernels/flash_attention.py`` is the tiling template).  The grid
  gains a minor-most F-step axis; each (c, j, i) cell accumulates the
  −2·XᵢXⱼᵀ cross term over F-tiles in an f32 VMEM scratch, and on the
  last F-step fuses the ‖·‖² epilogue, clamp, sqrt, exact self-distance
  zeroing (global row == global col), and the A/B (or add-cost) folds.
  Memory traffic drops from O(C·M²) to O(C·M·F) — per-client M in the
  thousands instead of hundreds.  Padded candidate columns (vf = 0) are
  masked to +BIG *in-kernel*: zero-padded feature rows are at distance 0
  from each other, so without the mask a padded lane could tie-win a
  medoid election over real rows.

Every kernel carries a leading client-batch grid dimension (one cohort
group = one launch), accepts masked lanes via ``vf`` (invalid rows
contribute exactly 0), and runs under ``interpret=True`` on CPU so the
whole fast path is exercised in CI.  Shapes must already be padded to
block multiples — ``repro.kernels.ops`` owns the padding and the jnp
fallback dispatch; ``repro.kernels.ref`` holds the mathematical oracles
the kernels are tested against (the from-feats refs DO materialize D —
that is exactly what makes them the parity gate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _build_cost_kernel(d_ref, dn_ref, vf_ref, out_ref, acc_ref, *, n_i: int):
    i_step = pl.program_id(2)

    @pl.when(i_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = d_ref[0].astype(jnp.float32)             # (bi, bj) distance tile
    dn = dn_ref[0].astype(jnp.float32)           # (bi,) current d_near
    vf = vf_ref[0].astype(jnp.float32)           # (bi,) valid mask
    add = jnp.minimum(dn[:, None], d) * vf[:, None]
    acc_ref[...] += jnp.sum(add, axis=0, keepdims=True)   # (1, bj)

    @pl.when(i_step == n_i - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def build_cost_pallas(D: jnp.ndarray, d_near: jnp.ndarray, vf: jnp.ndarray,
                      *, block_m: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """Fused BUILD add-cost: D (C, M, M), d_near/vf (C, M) -> (C, M).

    cost[c, j] = Σ_i min(d_near[c, i], D[c, i, j]) · vf[c, i], computed
    tile-by-tile without materializing the (C, M, M) minimum tensor.  M
    must be a multiple of ``block_m`` (ops.py pads; padded rows must
    carry vf = 0, padded cost columns are sliced off by the wrapper).
    """
    c, m, _ = D.shape
    block_m = min(block_m, m)
    assert m % block_m == 0
    n_i = m // block_m

    grid = (c, n_i, n_i)                          # (client, j-tile, i-step)
    kernel = functools.partial(_build_cost_kernel, n_i=n_i)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_m), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda b, j, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((c, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_m), jnp.float32)],
        interpret=interpret,
    )(D, d_near, vf)


def _delta_sweep_kernel(d_ref, d1_ref, d2_ref, vf_ref, oh_ref, a_ref, b_ref,
                        acc_a_ref, acc_b_ref, *, n_i: int):
    i_step = pl.program_id(2)

    @pl.when(i_step == 0)
    def _init():
        acc_a_ref[...] = jnp.zeros_like(acc_a_ref)
        acc_b_ref[...] = jnp.zeros_like(acc_b_ref)

    d = d_ref[0].astype(jnp.float32)             # (bi, bj)
    d1 = d1_ref[0].astype(jnp.float32)[:, None]  # (bi, 1) nearest-medoid dist
    d2 = d2_ref[0].astype(jnp.float32)[:, None]  # (bi, 1) second-nearest
    vf = vf_ref[0].astype(jnp.float32)[:, None]  # (bi, 1) valid mask
    oh = oh_ref[0].astype(jnp.float32)           # (bi, K) one_hot(n_idx)

    # one read of the tile feeds both reductions
    shift = (jnp.minimum(d, d1) - d1) * vf                 # ≤ 0 removal gain
    contrib = (jnp.clip(d, d1, d2) - d1) * vf              # per-cluster term
    acc_a_ref[...] += jnp.sum(shift, axis=0, keepdims=True)        # (1, bj)
    acc_b_ref[...] += jax.lax.dot_general(                 # contribᵀ @ onehot
        contrib, oh, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (bj, K)

    @pl.when(i_step == n_i - 1)
    def _epilogue():
        a_ref[...] = acc_a_ref[...].astype(a_ref.dtype)
        b_ref[0] = acc_b_ref[...].astype(b_ref.dtype)


def delta_sweep_pallas(D: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                       vf: jnp.ndarray, n_onehot: jnp.ndarray, *,
                       block_m: int = 128, interpret: bool = False):
    """Fused FasterPAM Δ-sweep reductions in one pass over D.

    D (C, M, M); d1/d2/vf (C, M); n_onehot (C, M, K) = one_hot of each
    point's nearest-medoid slot.  Returns (A (C, M), B (C, M, K)) such
    that Δ(j, l) = A[:, j] + B[:, j, l].  M must be a multiple of
    ``block_m`` and K a lane-aligned pad of the true k (ops.py owns the
    padding; padded rows carry vf = 0, padded K columns have zero
    one-hot mass so the extra B columns are exactly 0).
    """
    c, m, _ = D.shape
    kp = n_onehot.shape[-1]
    block_m = min(block_m, m)
    assert m % block_m == 0
    n_i = m // block_m

    grid = (c, n_i, n_i)                          # (client, j-tile, i-step)
    kernel = functools.partial(_delta_sweep_kernel, n_i=n_i)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_m), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_m, kp), lambda b, j, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, j)),
            pl.BlockSpec((1, block_m, kp), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, m), jnp.float32),
            jax.ShapeDtypeStruct((c, m, kp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_m), jnp.float32),
                        pltpu.VMEM((block_m, kp), jnp.float32)],
        interpret=interpret,
    )(D, d1, d2, vf, n_onehot)


# ---------------------------------------------------------------------------
# distance-free variants: D rebuilt per tile from the (C, M, F) features
# ---------------------------------------------------------------------------

BIG = 1e30      # matches repro.core.kmedoids.BIG (the +inf candidate mask)


def _dist_tile(dot, sqi, sqj, i_step, j_step, block_m):
    """One (bi, bj) L2-distance tile from its accumulated cross term.

    ``‖a − b‖ = sqrt(max(‖a‖² + ‖b‖² − 2ab, 0))`` with the self-distance
    diagonal (global row index == global col index) pinned to exact 0 —
    the float32 cancellation fix-up ``ops.zero_self_diag`` applies to
    materialized stacks, fused into the tile here."""
    d = jnp.sqrt(jnp.maximum(sqi[:, None] + sqj[None, :] - 2.0 * dot, 0.0))
    rows = i_step * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, block_m), 0)
    cols = j_step * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, block_m), 1)
    return jnp.where(rows == cols, 0.0, d)


def _build_cost_feats_kernel(xi_ref, xj_ref, sqi_ref, sqj_ref, dn_ref,
                             vfi_ref, vfj_ref, out_ref, dot_ref, acc_ref, *,
                             n_i: int, n_k: int, block_m: int):
    j_step = pl.program_id(1)
    i_step = pl.program_id(2)
    k_step = pl.program_id(3)

    @pl.when((i_step == 0) & (k_step == 0))
    def _init_cost():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k_step == 0)
    def _init_dot():
        dot_ref[...] = jnp.zeros_like(dot_ref)

    xi = xi_ref[0].astype(jnp.float32)           # (bi, bk) feature rows
    xj = xj_ref[0].astype(jnp.float32)           # (bj, bk) candidate rows
    dot_ref[...] += jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # xi @ xj.T

    @pl.when(k_step == n_k - 1)
    def _fold():
        d = _dist_tile(dot_ref[...], sqi_ref[0].astype(jnp.float32),
                       sqj_ref[0].astype(jnp.float32), i_step, j_step,
                       block_m)
        dn = dn_ref[0].astype(jnp.float32)       # (bi,) current d_near
        vf = vfi_ref[0].astype(jnp.float32)      # (bi,) valid rows
        add = jnp.minimum(dn[:, None], d) * vf[:, None]
        acc_ref[...] += jnp.sum(add, axis=0, keepdims=True)   # (1, bj)

    @pl.when((i_step == n_i - 1) & (k_step == n_k - 1))
    def _epilogue():
        vfj = vfj_ref[0].astype(jnp.float32)     # (bj,) valid candidates
        cost = jnp.where(vfj[None, :] > 0.0, acc_ref[...], BIG)
        out_ref[...] = cost.astype(out_ref.dtype)


def build_cost_from_feats_pallas(x: jnp.ndarray, d_near: jnp.ndarray,
                                 vf: jnp.ndarray, *, block_m: int = 128,
                                 block_k: int = 128,
                                 interpret: bool = False) -> jnp.ndarray:
    """Distance-free BUILD add-cost: x (C, M, F), d_near/vf (C, M) -> (C, M).

    cost[c, j] = Σ_i min(d_near[c, i], ‖x_i − x_j‖)·vf[c, i] for valid
    candidates j, +BIG for padded ones (vf[c, j] = 0) — the (C, M, M)
    distance stack is never materialized; each tile's distances are
    rebuilt from an F-tiled cross-term accumulation.  M must be a
    multiple of ``block_m`` and F of ``block_k`` (ops.py pads; zero
    feature rows/cols are exact for the cross term)."""
    c, m, f = x.shape
    block_m = min(block_m, m)
    block_k = min(block_k, f)
    assert m % block_m == 0 and f % block_k == 0
    n_i = m // block_m
    n_k = f // block_k
    sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)        # (C, M)

    grid = (c, n_i, n_i, n_k)            # (client, j-tile, i-step, F-step)
    kernel = functools.partial(_build_cost_feats_kernel, n_i=n_i, n_k=n_k,
                               block_m=block_m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda b, j, i, k: (b, i, k)),
            pl.BlockSpec((1, block_m, block_k), lambda b, j, i, k: (b, j, k)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, j)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, j)),
        out_shape=jax.ShapeDtypeStruct((c, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_m), jnp.float32),
                        pltpu.VMEM((1, block_m), jnp.float32)],
        interpret=interpret,
    )(x, x, sq, sq, d_near, vf, vf)


def _delta_sweep_feats_kernel(xi_ref, xj_ref, sqi_ref, sqj_ref, d1_ref,
                              d2_ref, vfi_ref, vfj_ref, oh_ref, a_ref, b_ref,
                              dot_ref, acc_a_ref, acc_b_ref, *, n_i: int,
                              n_k: int, block_m: int):
    j_step = pl.program_id(1)
    i_step = pl.program_id(2)
    k_step = pl.program_id(3)

    @pl.when((i_step == 0) & (k_step == 0))
    def _init_acc():
        acc_a_ref[...] = jnp.zeros_like(acc_a_ref)
        acc_b_ref[...] = jnp.zeros_like(acc_b_ref)

    @pl.when(k_step == 0)
    def _init_dot():
        dot_ref[...] = jnp.zeros_like(dot_ref)

    xi = xi_ref[0].astype(jnp.float32)           # (bi, bk)
    xj = xj_ref[0].astype(jnp.float32)           # (bj, bk)
    dot_ref[...] += jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _fold():
        d = _dist_tile(dot_ref[...], sqi_ref[0].astype(jnp.float32),
                       sqj_ref[0].astype(jnp.float32), i_step, j_step,
                       block_m)
        d1 = d1_ref[0].astype(jnp.float32)[:, None]   # (bi, 1)
        d2 = d2_ref[0].astype(jnp.float32)[:, None]
        vf = vfi_ref[0].astype(jnp.float32)[:, None]
        oh = oh_ref[0].astype(jnp.float32)            # (bi, K)
        shift = (jnp.minimum(d, d1) - d1) * vf        # ≤ 0 removal gain
        contrib = (jnp.clip(d, d1, d2) - d1) * vf     # per-cluster term
        acc_a_ref[...] += jnp.sum(shift, axis=0, keepdims=True)   # (1, bj)
        acc_b_ref[...] += jax.lax.dot_general(        # contribᵀ @ onehot
            contrib, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bj, K)

    @pl.when((i_step == n_i - 1) & (k_step == n_k - 1))
    def _epilogue():
        vfj = vfj_ref[0].astype(jnp.float32)          # (bj,)
        a = jnp.where(vfj[None, :] > 0.0, acc_a_ref[...], BIG)
        a_ref[...] = a.astype(a_ref.dtype)
        b_ref[0] = acc_b_ref[...].astype(b_ref.dtype)


def delta_sweep_from_feats_pallas(x: jnp.ndarray, d1: jnp.ndarray,
                                  d2: jnp.ndarray, vf: jnp.ndarray,
                                  n_onehot: jnp.ndarray, *,
                                  block_m: int = 128, block_k: int = 128,
                                  interpret: bool = False):
    """Distance-free FasterPAM Δ-sweep: the A/B reductions straight from
    the feature stack.

    x (C, M, F); d1/d2/vf (C, M); n_onehot (C, M, K).  Returns
    (A (C, M), B (C, M, K)) with Δ(j, l) = A[:, j] + B[:, j, l] and
    A[:, j] = +BIG for padded candidates (vf[:, j] = 0) so a zero-padded
    feature row can never tie-win a swap.  Distances are rebuilt per
    (i, j) tile from an F-tiled cross-term accumulation — no (C, M, M)
    intermediate.  M must be a multiple of ``block_m``, F of
    ``block_k``, K lane-aligned (ops.py owns the padding)."""
    c, m, f = x.shape
    kp = n_onehot.shape[-1]
    block_m = min(block_m, m)
    block_k = min(block_k, f)
    assert m % block_m == 0 and f % block_k == 0
    n_i = m // block_m
    n_k = f // block_k
    sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)        # (C, M)

    grid = (c, n_i, n_i, n_k)            # (client, j-tile, i-step, F-step)
    kernel = functools.partial(_delta_sweep_feats_kernel, n_i=n_i, n_k=n_k,
                               block_m=block_m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda b, j, i, k: (b, i, k)),
            pl.BlockSpec((1, block_m, block_k), lambda b, j, i, k: (b, j, k)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, j)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, i)),
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, j)),
            pl.BlockSpec((1, block_m, kp), lambda b, j, i, k: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda b, j, i, k: (b, j)),
            pl.BlockSpec((1, block_m, kp), lambda b, j, i, k: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, m), jnp.float32),
            jax.ShapeDtypeStruct((c, m, kp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, block_m), jnp.float32),
                        pltpu.VMEM((1, block_m), jnp.float32),
                        pltpu.VMEM((block_m, kp), jnp.float32)],
        interpret=interpret,
    )(x, x, sq, sq, d1, d2, vf, vf, n_onehot)
