"""Minimal optimizer library (no optax in this container).

An ``Optimizer`` is an (init, update) pair operating on pytrees:

    opt = adam(3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = tree_add(params, updates)

Learning rates may be floats or callables step -> lr (schedules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_add, tree_scale

LR = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def _lr_at(lr: LR, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr: LR, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(grads, state, params=None):
        step = state["step"]
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"],
                              grads)
            if nesterov:
                upd = jax.tree.map(lambda m, g: -(lr_t) * (momentum * m + g),
                                   mu, grads)
            else:
                upd = tree_scale(mu, -lr_t)
            return upd, {"step": step + 1, "mu": mu}
        return tree_scale(grads, -lr_t), {"step": step + 1}

    return Optimizer(init, update)


def adam(lr: LR, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: LR, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay)


def _adam_impl(lr: LR, b1, b2, eps, weight_decay) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, state["step"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"],
                         grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p_):
            u = -(lr_t) * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p_ is not None:
                u = u - lr_t * weight_decay * p_
            return u

        if weight_decay and params is not None:
            updates = jax.tree.map(upd, m, v, params)
        else:
            updates = jax.tree.map(lambda m_, v_: upd(m_, v_, None), m, v)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    from repro.utils.tree import global_norm
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return tree_scale(grads, scale), norm
