"""Learning-rate schedules (callables step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_time_lr(alpha: float, beta: float):
    """Paper Thm A.7 schedule: eta_t = alpha / (t + beta)."""
    return lambda step: alpha / (step.astype(jnp.float32) + beta)


def cosine_lr(base: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine_lr(base: float, warmup: int, total_steps: int,
                     final_frac: float = 0.1):
    cos = cosine_lr(base, max(1, total_steps - warmup), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        warm = base * s / max(1, warmup)
        return jnp.where(s < warmup, warm, cos(step - warmup))
    return f
