from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    sgd,
    adam,
    adamw,
    clip_by_global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant_lr,
    cosine_lr,
    inverse_time_lr,
    warmup_cosine_lr,
)
