"""Configuration system for repro models, shapes and meshes.

Every assigned architecture gets a ``ModelConfig`` (exact published dims) in
``src/repro/configs/<arch>.py``; reduced smoke variants are derived with
``smoke_variant``.  Input shapes are the four assigned workload shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm | xlstm
    source: str = ""       # citation / model card

    # transformer backbone
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1000
    d_head: Optional[int] = None          # default: d_model // n_heads
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    use_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"                     # silu (swiglu) | gelu (plain mlp)
    attention_window: Optional[int] = None  # sliding-window size (None = full)
    remat: bool = False                   # activation checkpointing per layer

    # MoE
    n_experts: int = 0                    # 0 = dense FFN
    moe_top_k: int = 1
    moe_capacity_factor: float = 1.25
    use_shared_expert: bool = True        # llama4-style shared expert
    router_aux_coef: float = 0.01

    # SSM / Mamba2
    ssm_state: int = 0                    # d_state (0 = no ssm layers)
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2-style): shared attention block every `attn_every` ssm layers
    attn_every: int = 0                   # 0 = not hybrid

    # xLSTM
    xlstm_pattern: str = ""               # e.g. "msmsmsmsmsms" (m=mLSTM, s=sLSTM)

    # enc-dec (audio): n_layers is the DECODER depth; encoder depth below
    enc_layers: int = 0                   # 0 = decoder-only
    enc_seq_frac: float = 0.5             # fraction of shape.seq used by encoder

    # vlm
    n_patches: int = 0                    # stub patch-embedding prefix length

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.arch_id}: q heads {self.n_heads} not divisible by kv "
            f"heads {self.n_kv_heads}")

    # -- derived -----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (analytic; used for roofline MODEL_FLOPS) ---------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hq, hk, hd = self.n_heads, self.n_kv_heads, self.d_head
        attn = d * hq * hd + 2 * d * hk * hd + hq * hd * d
        if self.act == "silu":
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        norms = 2 * d

        if self.family == "xlstm":
            per = _xlstm_layer_params(self)
            total = self.n_layers * per + v * d + d
            return int(total)
        if self.family in ("ssm", "hybrid") and self.ssm_state:
            mamba = _mamba2_layer_params(self)
            if self.family == "hybrid" and self.attn_every:
                n_attn_calls = self.n_layers // self.attn_every
                shared = attn + ffn + norms + 2 * d * d  # concat-proj
                total = self.n_layers * (mamba + d) + shared
            else:
                total = self.n_layers * (mamba + d)
            total += v * d + d + (0 if self.tie_embeddings else v * d)
            return int(total)

        per_layer = attn + norms
        if self.n_experts > 0:
            per_layer += self.n_experts * ffn + d * self.n_experts
            if self.use_shared_expert:
                per_layer += ffn
        else:
            per_layer += ffn
        total = self.n_layers * per_layer
        if self.enc_layers:
            # encoder self-attn + mlp, decoder gets extra cross-attn
            total += self.enc_layers * (attn + ffn + norms)
            total += self.n_layers * (attn + d)
        total += v * d + d
        if not self.tie_embeddings:
            total += v * d
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts + shared)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        ffn = 3 * d * f if self.act == "silu" else 2 * d * f
        inactive = (self.n_experts - self.moe_top_k) * ffn * self.n_layers
        return self.param_count() - int(inactive)


def _mamba2_layer_params(cfg: ModelConfig) -> int:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    in_proj = d * (2 * di + 2 * ds + nh)
    conv = (di + 2 * ds) * cfg.ssm_conv
    out_proj = di * d
    extra = nh * 2 + di  # A, D, dt_bias-ish + norm
    return in_proj + conv + out_proj + extra


def _xlstm_layer_params(cfg: ModelConfig) -> int:
    # mirrors models/xlstm.py init exactly
    d = cfg.d_model
    hd = d // cfg.n_heads
    # mLSTM block: wq,wk,wv + i/f gates + o-gate + out proj
    m = 3 * d * d + 2 * d * cfg.n_heads + d * d + d * d
    # sLSTM block: input proj (4 gates) + block-diag recurrent + out proj
    s = 4 * d * d + 4 * cfg.n_heads * hd * hd + d * d
    return (m + s) // 2 + 3 * d


# ---------------------------------------------------------------------------
# Workload shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Reduced smoke variants (2 layers, d_model <= 512, <= 4 experts)
# ---------------------------------------------------------------------------


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    kw = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_model // n_heads,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=16 if cfg.ssm_state else cfg.ssm_chunk,
        attn_every=1 if cfg.attn_every else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        n_patches=8 if cfg.n_patches else 0,
        xlstm_pattern=cfg.xlstm_pattern[:2] if cfg.xlstm_pattern else "",
        attention_window=(min(cfg.attention_window, 64)
                          if cfg.attention_window else None),
    )
    return cfg.with_(**kw)
