"""Config registry: the 10 assigned architectures + the paper's own models.

``get_config(arch_id)`` returns the exact published dims; pass
``smoke=True`` for the reduced CPU-testable variant (2 layers, d_model<=256,
<=4 experts) used by the per-arch smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

from repro.configs.base import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                                DECODE_32K, ModelConfig, ShapeConfig,
                                smoke_variant)

_MODULES = {
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-tiny": "whisper_tiny",
    "mistral-large-123b": "mistral_large_123b",
    "yi-9b": "yi_9b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "command-r-35b": "command_r_35b",
    "granite-20b": "granite_20b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "xlstm-125m": "xlstm_125m",
    "pixtral-12b": "pixtral_12b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False,
               shape: Optional[ShapeConfig] = None) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    if shape is not None:
        cfg = adapt_for_shape(cfg, shape)
    if smoke:
        cfg = smoke_variant(cfg)
    return cfg


def adapt_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-dependent config adjustments.

    ``long_500k`` requires sub-quadratic attention: every attention-bearing
    arch switches to a sliding window (DESIGN.md §3); SSM/xLSTM layers are
    unaffected (O(1) state).
    """
    if shape.name == "long_500k" and cfg.family != "xlstm":
        return cfg.with_(attention_window=8192)
    return cfg


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
