"""whisper-tiny [audio]: enc-dec backbone; conv/mel frontend is a stub
(input_specs provides precomputed frame embeddings).

4L (enc) + 4L (dec) d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865.
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=4,            # decoder depth
    enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    enc_seq_frac=0.5,
)
