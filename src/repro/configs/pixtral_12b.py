"""pixtral-12b [vlm]: mistral-nemo decoder consuming pixtral-ViT patch
embeddings (ViT frontend is a stub; input_specs provides patch embeddings).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    n_patches=1024,
)
