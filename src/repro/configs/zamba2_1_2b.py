"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.

38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
[arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    attn_every=6,          # shared attention block applied every 6 mamba layers
    tie_embeddings=True,
)
