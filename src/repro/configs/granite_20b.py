"""granite-20b [dense]: llama-arch (code), MQA.  52L d_model=6144 48H
(MQA kv=1) d_ff=24576 vocab=49152.  [arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
)
