"""xlstm-125m [ssm]: sLSTM + mLSTM blocks, alternating.  12L d_model=768
4H d_ff=0 (in-block projections) vocab=50304.  [arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="xlstm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_pattern="msmsmsmsmsms",
    tie_embeddings=True,
)
