"""repro: FedCore (straggler-free FL with distributed coresets) in JAX.

Public entry points:
  repro.core        — coreset selection (the paper's contribution)
  repro.fed         — federated runtime + strategies
  repro.models      — model zoo (assigned architectures + paper models)
  repro.kernels     — Pallas TPU kernels (ops/ref)
  repro.configs     — architecture registry
  repro.launch      — train / serve / dryrun drivers
"""
__version__ = "1.0.0"
