"""Pytree utilities used across the framework (no optax/flax available)."""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_weighted_mean(trees: Sequence[Pytree], weights: Sequence[float]) -> Pytree:
    """FedAvg-style aggregation: sum_i w_i * tree_i / sum_i w_i."""
    ws = jnp.asarray(weights, dtype=jnp.float32)
    ws = ws / jnp.sum(ws)

    def combine(*leaves):
        out = leaves[0] * ws[0]
        for i in range(1, len(leaves)):
            out = out + leaves[i] * ws[i]
        return out

    return jax.tree.map(combine, *trees)


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def param_count(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_allclose(a: Pytree, b: Pytree, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))
