"""Subprocess environments for forced multi-device CPU runs.

XLA fixes the host-platform device count when jax initializes, so any
harness that wants to compare device counts (the sharded-fleet scaling
sweep, the 4-virtual-device parity test) must re-exec itself with
``--xla_force_host_platform_device_count=N`` set *before* import.  This
is the one shared builder for that environment, so the flag-rewrite
rules can't drift between callers.
"""
from __future__ import annotations

import os
import re
from typing import Dict, Optional


def force_host_devices_here(n_devices: int) -> None:
    """Pin THIS process's ``XLA_FLAGS`` to ``n_devices`` virtual CPU devices.

    In-place sibling of ``forced_host_device_env`` for entry points that
    own their process (the dryrun CLI).  XLA reads the flag once when the
    backend initializes — the first ``jax.devices()`` / array op — so
    calling this after ``import jax`` but before any jax *use* is still
    effective.  Any pre-existing forced count is stripped first, same
    rewrite rule as the subprocess builder.
    """
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + flags).strip()


def forced_host_device_env(n_devices: int,
                           repo_root: Optional[str] = None
                           ) -> Dict[str, str]:
    """A copy of ``os.environ`` pinned to ``n_devices`` virtual CPU devices.

    Any pre-existing forced device count in ``XLA_FLAGS`` is stripped
    first (the parent may itself be a forced-device process — e.g. the CI
    multi-device job).  ``repo_root``, when given, prepends its ``src``
    directory to ``PYTHONPATH`` so the child can import ``repro`` no
    matter how the parent was launched.
    """
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + flags).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    if repo_root is not None:
        src = os.path.join(os.path.abspath(repo_root), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env
