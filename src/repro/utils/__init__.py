from repro.utils.tree import (  # noqa: F401
    tree_add,
    tree_scale,
    tree_sub,
    tree_weighted_mean,
    tree_zeros_like,
    global_norm,
    param_count,
    tree_allclose,
)
