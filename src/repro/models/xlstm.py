"""xLSTM blocks (Beck et al., arXiv:2405.04517): sLSTM and mLSTM cells.

Both cells use exponential gating with a log-domain stabilizer state m.
Train/prefill run the recurrence with a single ``lax.scan`` over time (one
while-loop in HLO — compile-size friendly); decode is the same cell applied
to one step.  State is O(1) per token, so xLSTM runs ``long_500k`` natively.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


class MLSTMState(NamedTuple):
    C: jnp.ndarray  # (B, H, hd, hd)
    n: jnp.ndarray  # (B, H, hd)
    m: jnp.ndarray  # (B, H)


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, H, hd)
    n: jnp.ndarray  # (B, H, hd)
    h: jnp.ndarray  # (B, H, hd)
    m: jnp.ndarray  # (B, H, hd)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "norm": init_rmsnorm(d),
        "wq": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, d),
        "wv": dense_init(ks[2], d, d),
        "wi": dense_init(ks[3], d, h, scale=0.02),
        "wf": dense_init(ks[4], d, h, scale=0.02),
        "bf": jnp.full((h,), 3.0),  # forget-bias init keeps early memory
        "bi": jnp.zeros((h,)),
        "wo_gate": dense_init(ks[5], d, d),
        "w_out": dense_init(ks[6], d, d),
    }


def _mlstm_cell(state: MLSTMState, q, k, v, i_pre, f_pre):
    """One step.  q/k/v: (B,H,hd); i_pre/f_pre: (B,H)."""
    C, n, m = state
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    f_act = jnp.exp(log_f + m - m_new)          # (B,H)
    i_act = jnp.exp(i_pre - m_new)
    C_new = C * f_act[..., None, None] + i_act[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", v, k)
    n_new = n * f_act[..., None] + i_act[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                        jnp.exp(-m_new))
    h_t = jnp.einsum("bhde,bhe->bhd", C_new, q) / denom[..., None]
    return MLSTMState(C_new, n_new, m_new), h_t


def mlstm_block(params, cfg: ModelConfig, x, state: MLSTMState | None = None,
                *, decode: bool = False):
    """x: (B, S, d) -> (B, S, d), new state."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = (xn @ params["wq"]).reshape(b, s, h, hd) / jnp.sqrt(hd)
    k = (xn @ params["wk"]).reshape(b, s, h, hd) / jnp.sqrt(hd)
    v = (xn @ params["wv"]).reshape(b, s, h, hd)
    i_pre = xn @ params["wi"] + params["bi"]
    f_pre = xn @ params["wf"] + params["bf"]
    if state is None:
        state = init_mlstm_state(cfg, b, x.dtype)

    if decode:
        state, h_t = _mlstm_cell(state, q[:, 0], k[:, 0], v[:, 0],
                                 i_pre[:, 0], f_pre[:, 0])
        hs = h_t[:, None]
    else:
        def step(st, inp):
            return _mlstm_cell(st, *inp)
        state, hs = jax.lax.scan(
            step, state,
            (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
             v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
             f_pre.transpose(1, 0, 2)))
        hs = hs.transpose(1, 0, 2, 3)
    o = jax.nn.sigmoid(xn @ params["wo_gate"])
    out = (hs.reshape(b, s, d) * o) @ params["w_out"]
    return x + out, state


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    return MLSTMState(
        C=jnp.zeros((batch, h, hd, hd), dtype),
        n=jnp.zeros((batch, h, hd), dtype),
        m=jnp.full((batch, h), -1e30, dtype),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 4)
    return {
        "norm": init_rmsnorm(d),
        # input projections for gates z, i, f, o
        "w_in": dense_init(ks[0], d, 4 * d),
        # block-diagonal recurrent weights per head per gate
        "r": jax.random.normal(ks[1], (4, h, hd, hd)) / jnp.sqrt(hd),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0),
                              jnp.zeros((d,))]),
        "w_out": dense_init(ks[2], d, d),
        "out_norm": init_rmsnorm(d),
    }


def _slstm_cell(params, cfg: ModelConfig, state: SLSTMState, x_gates):
    """x_gates: (B, 4, H, hd) pre-activations from the input projection."""
    c, n, h_prev, m = state
    hcat = h_prev  # (B, H, hd)
    rec = jnp.einsum("ghde,bhe->bghd", params["r"], hcat)  # (B,4,H,hd)
    pre = x_gates + rec
    z_pre, i_pre, f_pre, o_pre = (pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3])
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    f_act = jnp.exp(log_f + m - m_new)
    i_act = jnp.exp(i_pre - m_new)
    c_new = f_act * c + i_act * z
    n_new = f_act * n + i_act
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, h_new, m_new), h_new


def slstm_block(params, cfg: ModelConfig, x, state: SLSTMState | None = None,
                *, decode: bool = False):
    b, s, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    gates = (xn @ params["w_in"] + params["b"]).reshape(b, s, 4, h, hd)
    if state is None:
        state = init_slstm_state(cfg, b, x.dtype)
    if decode:
        state, h_t = _slstm_cell(params, cfg, state, gates[:, 0])
        hs = h_t[:, None]
    else:
        def step(st, g):
            return _slstm_cell(params, cfg, st, g)
        state, hs = jax.lax.scan(step, state, gates.transpose(1, 0, 2, 3, 4))
        hs = hs.transpose(1, 0, 2, 3)
    out = rmsnorm(params["out_norm"], hs.reshape(b, s, d), cfg.norm_eps)
    return x + out @ params["w_out"], state


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, hd), dtype)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, h, hd), -1e30, dtype))
