"""Generic (jit-able) train/eval step factories.

Works for both the paper's small FL models and the large ``Model`` family —
anything exposing ``loss(params, batch) -> (scalar, metrics)``.

FedProx support: ``prox_mu > 0`` adds (mu/2)||w - w_ref||² against the
round-start global model (passed as ``prox_ref`` to the step).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.utils.tree import tree_add


def prox_term(params, ref):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda a, b: jnp.sum(jnp.square(a - b)), params, ref))
    return sum(leaves)


def make_train_step(loss_fn: Callable, optimizer: Optimizer, *,
                    prox_mu: float = 0.0, clip_norm: Optional[float] = None,
                    accum_steps: int = 1, donate: bool = True):
    """loss_fn(params, batch) -> (scalar, metrics).

    ``accum_steps > 1`` enables gradient accumulation (microbatching): the
    batch's leading dim is split into `accum_steps` microbatches whose
    gradients are averaged in a lax.scan before the single optimizer
    update — the §Perf H1 production fix for activation memory (peak
    activations shrink by ~accum_steps at unchanged math).
    """

    def grads_of(params, batch, prox_ref):
        def total_loss(p):
            loss, metrics = loss_fn(p, batch)
            if prox_mu and prox_ref is not None:
                loss = loss + 0.5 * prox_mu * prox_term(p, prox_ref)
            return loss, metrics
        return jax.value_and_grad(total_loss, has_aux=True)(params)

    def step(params, opt_state, batch, prox_ref=None):
        if accum_steps > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), g = grads_of(params, mb, prox_ref)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), metrics

            zeros = jax.tree.map(jnp.zeros_like, params)
            (gsum, loss_sum), metrics = jax.lax.scan(
                body, (zeros, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = loss_sum / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(params, batch, prox_ref)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = tree_add(params, updates)
        metrics = dict(metrics, total_loss=loss)
        return params, opt_state, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


def make_eval_step(loss_fn: Callable):
    @jax.jit
    def step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return step


def make_grad_fn(loss_fn: Callable):
    """Full-batch gradient (used by the ε-coreset audit)."""
    @jax.jit
    def grad_fn(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics
    return grad_fn
