"""Mamba2 (SSD) block in pure JAX: chunked-parallel scan for train/prefill,
O(1)-state single-token recurrence for decode.

Follows the SSD "minimal" formulation (Dao & Gu 2024): per-head scalar decay
A, per-token dt, shared (ngroups=1) B/C projections of state size N:

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T        h: (heads, headdim, N)
    y_t = C_t . h_t + D x_t

The chunked algorithm computes intra-chunk contributions with a quadratic
(MXU-friendly) einsum and carries inter-chunk states with a short lax.scan —
the TPU-native adaptation of the paper-era CUDA selective-scan kernels.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


class MambaState(NamedTuple):
    ssm: jnp.ndarray   # (B, nh, hd, N)
    conv: jnp.ndarray  # (B, k-1, conv_channels)


def init_mamba2(key, cfg: ModelConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    return {
        "norm_in": init_rmsnorm(d),
        "w_in": dense_init(ks[0], d, 2 * di + 2 * n + nh),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1,
        "conv_b": jnp.zeros((conv_ch,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "D": jnp.ones((nh,)),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse softplus
        "norm": init_rmsnorm(di),
        "w_out": dense_init(ks[3], di, d),
    }


# ---------------------------------------------------------------------------
# chunked SSD scan
# ---------------------------------------------------------------------------

def _segsum(a):
    """a: (..., L) -> (..., L, L) with out[t, s] = sum_{s < t' <= t} a[t']."""
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    L = a.shape[-1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int, h0=None):
    """Chunk-parallel SSD.

    x: (b, s, nh, hd)   token inputs (already multiplied by dt)
    a: (b, s, nh)       log-decay per step (dt * A, negative)
    B, C: (b, s, n)     shared across heads (ngroups = 1)
    h0: (b, nh, hd, n)  initial state (decode continuation) or None.
    Returns y: (b, s, nh, hd), h_final: (b, nh, hd, n).
    """
    b, s, nh, hd = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, nh, hd)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    ac = a.reshape(b, nc, chunk, nh).transpose(0, 3, 1, 2)    # (b,nh,nc,l)

    a_cs = jnp.cumsum(ac, axis=-1)                            # (b,nh,nc,l)
    L = jnp.exp(_segsum(ac))                                  # (b,nh,nc,l,l)

    # intra-chunk (quadratic, MXU)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # per-chunk end states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)             # (b,nh,nc,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])                      # (b,nh,nc)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), x.dtype)

    def step(h, inp):
        st, dec = inp                                         # (b,nh,hd,n),(b,nh)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    (h_final, prev_states) = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,nh,hd,n)

    # inter-chunk contribution
    out_decay = jnp.exp(a_cs)                                 # (b,nh,nc,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, out_decay)

    y = (y_diag + y_off).reshape(b, nc * chunk, nh, hd)
    return y[:, :s], h_final


def ssd_sequential(x, a, B, C, h0=None):
    """Step-by-step oracle for tests; same signature as ssd_chunked."""
    b, s, nh, hd = x.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), x.dtype)

    def step(h, inp):
        x_t, a_t, B_t, C_t = inp
        h = h * jnp.exp(a_t)[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x_t, B_t)
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    h, ys = jax.lax.scan(step, h0, (x.transpose(1, 0, 2, 3),
                                    a.transpose(1, 0, 2),
                                    B.transpose(1, 0, 2),
                                    C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), h


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x, w, b):
    """x: (B, S, C), w: (K, C) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.transpose(0, 2, 1)[:, :, None, :],          # NCHW (B, C, 1, S+k-1)
        w.T[:, None, None, :],                          # OIHW (C, 1, 1, K)
        window_strides=(1, 1), padding="VALID",
        feature_group_count=x.shape[-1])
    return out[:, :, 0, :].transpose(0, 2, 1) + b       # (B, S, C)


def causal_conv_step(state, x_t, w, b):
    """state: (B, K-1, C) previous inputs; x_t: (B, 1, C)."""
    window = jnp.concatenate([state, x_t], axis=1)      # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return window[:, 1:], y[:, None, :]


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, proj):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def mamba2_block(params, cfg: ModelConfig, u, state: MambaState | None = None,
                 *, decode: bool = False):
    """u: (B, S, d_model) -> (B, S, d_model), new_state.

    decode=True requires S == 1 and a state.
    """
    b, s, d = u.shape
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    u = rmsnorm(params["norm_in"], u, cfg.norm_eps)
    proj = u @ params["w_in"]
    z, xbc, dt = _split_proj(cfg, proj)

    if decode:
        conv_state, y_conv = causal_conv_step(state.conv, xbc,
                                              params["conv_w"],
                                              params["conv_b"])
    else:
        y_conv = causal_conv(xbc, params["conv_w"], params["conv_b"])
        conv_state = None
        if state is not None:
            raise ValueError("prefill with prior state not supported")

    y_conv = jax.nn.silu(y_conv)
    x_in = y_conv[..., :di].reshape(b, s, nh, hd)
    B_in = y_conv[..., di:di + n]
    C_in = y_conv[..., di + n:]

    A = -jnp.exp(params["A_log"])                            # (nh,)
    dt_s = jax.nn.softplus(dt + params["dt_bias"])           # (b,s,nh)
    a = dt_s * A                                             # log decay
    x_dt = x_in * dt_s[..., None]

    if decode:
        h = state.ssm * jnp.exp(a[:, 0])[..., None, None]
        h = h + jnp.einsum("bhp,bn->bhpn", x_dt[:, 0], B_in[:, 0])
        y = jnp.einsum("bhpn,bn->bhp", h, C_in[:, 0])[:, None]
        h_final = h
    else:
        y, h_final = ssd_chunked(x_dt, a, B_in, C_in, cfg.ssm_chunk)

    y = y + x_in * params["D"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["w_out"]

    if decode:
        new_state = MambaState(ssm=h_final, conv=conv_state)
    else:
        k = cfg.ssm_conv
        conv_tail = jnp.pad(xbc, ((0, 0), (max(0, k - 1 - s), 0), (0, 0)))
        new_state = MambaState(ssm=h_final, conv=conv_tail[:, -(k - 1):])
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return MambaState(
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                      dtype),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state),
                       dtype),
    )
