"""Grouped-query attention with RoPE, sliding windows, KV-cache decode.

Three implementations share one math definition:
  * ``naive``    — materializes the (S, S) score matrix (small seq / oracle)
  * ``chunked``  — flash-style online-softmax over KV blocks inside a scan
                   over Q blocks; O(S * block) memory, lowers on any backend.
  * ``pallas``   — the TPU kernel in ``repro.kernels.flash_attention``
                   (validated vs `naive` in interpret mode; selected only when
                   running on real TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, hq * hd),
        "wk": dense_init(ks[1], d, hk * hd),
        "wv": dense_init(ks[2], d, hk * hd),
        "wo": dense_init(ks[3], hq * hd, d),
    }


# ---------------------------------------------------------------------------
# mask helpers
# ---------------------------------------------------------------------------

def _causal_window_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive bias (..., Sq, Sk) from position tensors."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok = ok & (dk <= dq)
    if window is not None:
        ok = ok & (dk > dq - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _gqa_scores(q, k):
    """q: (B,Sq,Hq,hd)  k: (B,Sk,Hk,hd) -> (B,Hq,Sq,Sk)."""
    b, sq, hq, hd = q.shape
    hk = k.shape[2]
    q = q.reshape(b, sq, hk, hq // hk, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    return s.reshape(b, hq, sq, k.shape[1])


def _gqa_out(p, v):
    """p: (B,Hq,Sq,Sk)  v: (B,Sk,Hk,hd) -> (B,Sq,Hq,hd)."""
    b, hq, sq, sk = p.shape
    hk = v.shape[2]
    p = p.reshape(b, hk, hq // hk, sq, sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(p.dtype))
    return o.reshape(b, sq, hq, v.shape[3])


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _attend_naive(q, k, v, bias, scale):
    s = _gqa_scores(q, k) * scale
    s = s + jnp.broadcast_to(bias, s.shape[-2:])  # (Sq,Sk) broadcast
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return _gqa_out(p, v).astype(q.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, causal, window, scale,
                    q_block: int = 512, kv_block: int = 1024):
    """Flash-style two-level blocking with online softmax (pure jnp/lax)."""
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    # pad to multiples
    pq = (-sq) % q_block
    pk = (-sk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, pq),), constant_values=2**30)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, pk),), constant_values=-(2**30))
    nq, nk = q.shape[1] // q_block, k.shape[1] // kv_block

    qb = q.reshape(b, nq, q_block, hq, hd).transpose(1, 0, 2, 3, 4)
    qpb = q_pos.reshape(nq, q_block)
    kb = k.reshape(b, nk, kv_block, k.shape[2], hd)
    vb = v.reshape(b, nk, kv_block, v.shape[2], hd)
    kpb = k_pos.reshape(nk, kv_block)

    def one_q_block(q_i, qp_i):
        # online softmax over kv blocks
        def step(carry, inp):
            m, l, acc = carry
            k_j, v_j, kp_j = inp
            bias = _causal_window_bias(qp_i, kp_j, causal, window)  # (qb,kb)
            s = _gqa_scores(q_i, k_j) * scale + bias                # (B,Hq,qb,kb)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bhsd->bhqd", p, _expand_kv(v_j, hq).astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_block), jnp.float32)
        a0 = jnp.zeros((b, hq, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), kpb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,qb,Hq,hd)

    out = jax.lax.map(lambda args: one_q_block(*args), (qb, qpb))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_block, hq, hd)
    return out[:, :sq]


def _expand_kv(kv, hq):
    """(B,S,Hk,hd) -> (B,S',Hq,hd) by repeating kv heads; returns (B,Hq,S,hd)."""
    b, s, hk, hd = kv.shape
    kv = jnp.repeat(kv, hq // hk, axis=2)
    return kv.transpose(0, 2, 1, 3)  # (B,Hq,S,hd)


def multihead_attention(params, cfg: ModelConfig, x, positions=None, *,
                        causal: bool = True, window: Optional[int] = None,
                        impl: str = "chunked", kv_x=None, kv_positions=None,
                        use_rope: bool = True):
    """Full-sequence attention. kv_x != None => cross-attention.

    x: (B, S, d); positions: (S,) int32.  Returns (B, S, d).
    """
    b, s, d = x.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    if kv_positions is None:
        kv_positions = (positions if kv_x is None
                        else jnp.arange(sk, dtype=jnp.int32))

    q = (x @ params["wq"]).reshape(b, s, hq, hd)
    k = (src @ params["wk"]).reshape(b, sk, hk, hd)
    v = (src @ params["wv"]).reshape(b, sk, hk, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    if impl == "naive":
        bias = _causal_window_bias(positions, kv_positions, causal, window)
        out = _attend_naive(q, k, v, bias, scale)
    elif impl == "chunked":
        out = _attend_chunked(q, k, v, positions, kv_positions, causal,
                              window, scale)
    elif impl == "pallas":
        # TPU kernel path (kernels/flash_attention.py); requires self-attn
        # with contiguous positions (train/prefill), which is the hot case.
        if kv_x is not None:
            out = _attend_chunked(q, k, v, positions, kv_positions, causal,
                                  window, scale)
        else:
            from repro.kernels.ops import flash_attention
            out = flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=causal, window=window,
                scale=float(scale)).transpose(0, 2, 1, 3)
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    return out.reshape(b, s, hq * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# KV-cache decode (one token)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, seq_len: int,
                  dtype=jnp.bfloat16):
    w = cfg.attention_window
    size = min(seq_len, w) if w else seq_len
    shape = (n_layers, batch, size, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_slot_positions(cache_size: int, pos, window: Optional[int]):
    """Position held by each ring-buffer slot at decode step `pos`.

    Full cache (window None): slot i holds position i (valid if i <= pos).
    Ring cache: slot i holds the largest p <= pos with p % size == i.
    """
    idx = jnp.arange(cache_size, dtype=jnp.int32)
    if window is None:
        return idx
    return pos - ((pos - idx) % cache_size)


def attention_decode(params, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                     window: Optional[int] = None, use_rope: bool = True):
    """One-token decode.

    x: (B, 1, d); cache_k/v: (B, S_cache, Hk, hd); pos: scalar int32 —
    position of the *new* token.  Returns (out (B,1,d), new_k, new_v).
    """
    b, _, d = x.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s_cache = cache_k.shape[1]

    q = (x @ params["wq"]).reshape(b, 1, hq, hd)
    k = (x @ params["wk"]).reshape(b, 1, hk, hd)
    v = (x @ params["wv"]).reshape(b, 1, hk, hd)
    posv = jnp.full((1,), pos, jnp.int32)
    if use_rope:
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)

    slot = pos % s_cache if window else jnp.minimum(pos, s_cache - 1)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))

    slot_pos = cache_slot_positions(s_cache, pos, window)
    valid = (slot_pos <= pos) & (slot_pos >= 0)
    if window:
        valid = valid & (slot_pos > pos - window)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # (S_cache,)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = _gqa_scores(q, cache_k.astype(q.dtype)) * scale          # (B,Hq,1,Sc)
    s = s + bias[None, None, None, :]
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = _gqa_out(p, cache_v).astype(x.dtype)                   # (B,1,Hq,hd)
    out = out.reshape(b, 1, hq * hd) @ params["wo"]
    return out, cache_k, cache_v


def cross_attention_decode(params, cfg: ModelConfig, x, enc_k, enc_v):
    """Decode-time cross attention over precomputed encoder K/V."""
    b = x.shape[0]
    hq, hd = cfg.n_heads, cfg.d_head
    q = (x @ params["wq"]).reshape(b, 1, hq, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = _gqa_scores(q, enc_k.astype(q.dtype)) * scale
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = _gqa_out(p, enc_v).astype(x.dtype)
    return out.reshape(b, 1, hq * hd) @ params["wo"]
