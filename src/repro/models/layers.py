"""Core neural building blocks (pure JAX, functional, explicit param dicts).

Parameter convention: every module is a pair of functions
  ``init_<mod>(key, cfg, ...) -> params`` and ``<mod>(params, x, ...) -> y``
with params as (nested) dicts of jnp arrays.  Layer stacks are stored
*stacked* on a leading ``L`` axis so the forward pass can ``lax.scan`` over
layers (keeps HLO size flat for 88-layer archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, d_head); positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (d_head/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# MLP / SwiGLU
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "w_gate": dense_init(ks[0], d, f),
            "w_up": dense_init(ks[1], d, f),
            "w_down": dense_init(ks[2], f, d),
        }
    return {
        "w_up": dense_init(ks[0], d, f),
        "w_down": dense_init(ks[1], f, d),
    }


def mlp(params, x, act: str = "silu"):
    if act == "silu":
        g = jax.nn.silu(x @ params["w_gate"])
        u = x @ params["w_up"]
        return (g * u) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# stacked-layer helpers
# ---------------------------------------------------------------------------

def init_stacked(key, n_layers: int, init_one):
    """Initialize `n_layers` copies of a module, stacked on a leading axis."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def unembed(params, h):
    """h: (..., d) -> logits (..., vocab)."""
    return h @ params["w_unembed"]
