"""Mixture-of-Experts FFN (Llama-4-style: top-1 routed + shared expert).

Dispatch uses the sort-based (MaxText-style) formulation rather than the
one-hot einsum dispatch: tokens are argsorted by routed expert, gathered into
an (E, C, d) buffer (capacity C per expert, overflow dropped), processed with
a single batched (E, C, d) x (E, d, f) einsum — which shards cleanly with the
expert axis on the mesh `model` axis (expert parallelism; the reshard is the
all-to-all) — and scattered back weighted by the router probability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (e, d, f)) / jnp.sqrt(d),
        "w_up": jax.random.normal(ks[2], (e, d, f)) / jnp.sqrt(d),
        "w_down": jax.random.normal(ks[3], (e, f, d)) / jnp.sqrt(f),
    }
    if cfg.use_shared_expert:
        params["shared"] = init_mlp(ks[4], cfg)
    return params


def _capacity(n_tokens: int, n_experts: int, factor: float) -> int:
    c = int(n_tokens * factor / n_experts)
    return max(8, min(n_tokens, c))


def moe_ffn(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d), aux_loss (scalar).

    Top-1 routing with capacity dropping; dropped tokens fall through on the
    residual (and the shared expert still processes every token).
    """
    b, s, d = x.shape
    e = cfg.n_experts
    n = b * s
    xt = x.reshape(n, d)

    logits = (xt @ params["router"]).astype(jnp.float32)       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    expert = jnp.argmax(probs, axis=-1)                        # (N,)
    gate = jnp.max(probs, axis=-1)                             # (N,)

    # --- load-balance auxiliary loss (Switch-style) ----------------------
    density = jnp.mean(jax.nn.one_hot(expert, e, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_proxy)

    # --- sort-based dispatch ---------------------------------------------
    cap = _capacity(n, e, cfg.moe_capacity_factor)
    order = jnp.argsort(expert)                                # (N,) stable
    sorted_expert = expert[order]
    # rank of each token within its expert group
    same = jax.nn.one_hot(sorted_expert, e, dtype=jnp.int32)   # (N, E)
    rank_all = jnp.cumsum(same, axis=0) - 1                    # (N, E)
    rank = jnp.take_along_axis(rank_all, sorted_expert[:, None], axis=1)[:, 0]
    keep = rank < cap
    slot = sorted_expert * cap + jnp.minimum(rank, cap - 1)    # (N,)
    # scatter tokens into (E*C, d); dropped tokens go to a scratch row
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    slot = jnp.where(keep, slot, e * cap)
    buf = buf.at[slot].set(xt[order], mode="drop")
    hidden = buf[: e * cap].reshape(e, cap, d)

    # --- expert compute (shards over E on the mesh model axis) ------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", hidden, params["w_up"])
    out = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])  # (E, C, d)

    # --- un-dispatch -------------------------------------------------------
    flat = jnp.concatenate([out.reshape(e * cap, d),
                            jnp.zeros((1, d), out.dtype)], axis=0)
    routed_sorted = flat[slot] * keep[:, None]                 # (N, d) sorted order
    inv = jnp.argsort(order)
    routed = routed_sorted[inv] * gate[:, None].astype(x.dtype)

    y = routed
    if cfg.use_shared_expert:
        y = y + mlp(params["shared"], xt, cfg.act)
    return y.reshape(b, s, d), aux


def moe_ffn_dense_oracle(params, cfg: ModelConfig, x):
    """Reference: every expert processes every token (no capacity drops).

    Used by tests to validate the sort-based dispatch on small shapes where
    capacity >= tokens-per-expert.
    """
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    expert = jnp.argmax(probs, axis=-1)
    gate = jnp.max(probs, axis=-1)
    g = jax.nn.silu(jnp.einsum("nd,edf->enf", xt, params["w_gate"]))
    u = jnp.einsum("nd,edf->enf", xt, params["w_up"])
    out = jnp.einsum("enf,efd->end", g * u, params["w_down"])
    sel = jnp.take_along_axis(out, expert[None, :, None], axis=0)[0]
    y = sel * gate[:, None].astype(x.dtype)
    if cfg.use_shared_expert:
        y = y + mlp(params["shared"], xt, cfg.act)
    density = jnp.mean(jax.nn.one_hot(expert, cfg.n_experts,
                                      dtype=jnp.float32), axis=0)
    aux = cfg.n_experts * jnp.sum(density * jnp.mean(probs, axis=0))
    return y.reshape(b, s, d), aux
