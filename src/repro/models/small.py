"""The paper's own evaluation models (§6.1): logistic regression for the
Synthetic(α,β) benchmark, a small CNN for (pseudo-)MNIST, and an LSTM
char-LM for the Shakespeare-style benchmark.

Each exposes the FLModel interface used by the federated runtime:
  init(key) -> params
  loss(params, batch) -> (scalar, metrics)        [supports batch["weights"]]
  accuracy(params, batch) -> scalar
  grad_features(params, batch) -> (B, F)          [FedCore §4.3 proxies]
  feature_space: "input" (convex d̃) or "last_layer_grad" (DNN d̂)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

IGNORE = -100


def _weighted_ce(logits, labels, weights=None):
    """logits (B, ..., C); labels (B, ...); weights (B,) or None."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = labels != IGNORE
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    nll = nll * valid
    axes = tuple(range(1, nll.ndim))
    per_example = (jnp.sum(nll, axis=axes)
                   / jnp.maximum(jnp.sum(valid, axis=axes), 1))
    if weights is None:
        weights = jnp.ones(per_example.shape[0], jnp.float32)
    total = jnp.sum(per_example * weights) / jnp.maximum(jnp.sum(weights),
                                                         1e-9)
    return total, per_example


def _last_layer_grad_feature(logits, labels, w_out):
    """FedCore §4.3 DNN proxy: dL/dz = (softmax(logits) - onehot(y)) W_outᵀ.

    logits (B, ..., C); w_out (F, C).  Token/position axes are mean-pooled
    so each *sample* yields one feature vector (the per-sample gradient the
    k-medoids clustering runs on).
    """
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    valid = (labels != IGNORE)
    safe = jnp.where(valid, labels, 0)
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=jnp.float32)
    dlogits = (p - onehot) * valid[..., None]
    feat = dlogits @ w_out.T.astype(jnp.float32)  # (B, ..., F)
    if feat.ndim > 2:
        axes = tuple(range(1, feat.ndim - 1))
        feat = (jnp.sum(feat, axis=axes)
                / jnp.maximum(jnp.sum(valid, axis=axes), 1)[..., None])
    return feat


# ---------------------------------------------------------------------------
# Logistic regression (Synthetic benchmark; convex -> input-space distances)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LogisticRegression:
    n_features: int = 60
    n_classes: int = 10
    feature_space: str = "input"

    def init(self, key):
        return {"w": jnp.zeros((self.n_features, self.n_classes)),
                "b": jnp.zeros((self.n_classes,))}

    def logits(self, params, x):
        return x @ params["w"] + params["b"]

    def loss(self, params, batch):
        logits = self.logits(params, batch["x"])
        total, per_example = _weighted_ce(logits, batch["y"],
                                          batch.get("weights"))
        return total, {"loss": total, "per_example_loss": per_example}

    def accuracy(self, params, batch):
        logits = self.logits(params, batch["x"])
        return jnp.mean(jnp.argmax(logits, -1) == batch["y"])

    def grad_features(self, params, batch):
        # convex model: paper uses input-space Euclidean distances (d̃)
        return batch["x"]


# ---------------------------------------------------------------------------
# Small CNN (MNIST benchmark)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SmallCNN:
    """Three-layer CNN: 2 conv (5x5) + 1 dense head, as in the paper."""
    image_size: int = 28
    channels: Tuple[int, int] = (16, 32)
    n_classes: int = 10
    feature_space: str = "last_layer_grad"

    def init(self, key):
        ks = jax.random.split(key, 4)
        c1, c2 = self.channels
        s = self.image_size // 4  # two 2x2 pools
        return {
            "conv1": jax.random.normal(ks[0], (5, 5, 1, c1)) * 0.1,
            "b1": jnp.zeros((c1,)),
            "conv2": jax.random.normal(ks[1], (5, 5, c1, c2)) * 0.1,
            "b2": jnp.zeros((c2,)),
            "w_out": dense_init(ks[2], s * s * c2, self.n_classes),
            "b_out": jnp.zeros((self.n_classes,)),
        }

    def _features(self, params, x):
        """x: (B, H, W) or (B, H, W, 1) -> (B, F) pre-head features."""
        if x.ndim == 3:
            x = x[..., None]
        for w, b in ((params["conv1"], params["b1"]),
                     (params["conv2"], params["b2"])):
            x = jax.lax.conv_general_dilated(
                x, w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
            x = jax.nn.relu(x)
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        return x.reshape(x.shape[0], -1)

    def logits(self, params, x):
        return self._features(params, x) @ params["w_out"] + params["b_out"]

    def loss(self, params, batch):
        logits = self.logits(params, batch["x"])
        total, per_example = _weighted_ce(logits, batch["y"],
                                          batch.get("weights"))
        return total, {"loss": total, "per_example_loss": per_example}

    def accuracy(self, params, batch):
        logits = self.logits(params, batch["x"])
        return jnp.mean(jnp.argmax(logits, -1) == batch["y"])

    def grad_features(self, params, batch):
        logits = self.logits(params, batch["x"])
        return _last_layer_grad_feature(logits, batch["y"], params["w_out"])


# ---------------------------------------------------------------------------
# LSTM char-LM (Shakespeare benchmark)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CharLSTM:
    vocab: int = 80
    d_embed: int = 8
    d_hidden: int = 128
    n_layers: int = 2
    feature_space: str = "last_layer_grad"

    def init(self, key):
        ks = jax.random.split(key, 2 + self.n_layers)
        params = {
            "embed": jax.random.normal(ks[0], (self.vocab, self.d_embed))
            * 0.1,
            "w_out": dense_init(ks[1], self.d_hidden, self.vocab),
            "b_out": jnp.zeros((self.vocab,)),
        }
        d_in = self.d_embed
        for i in range(self.n_layers):
            k1, k2 = jax.random.split(ks[2 + i])
            params[f"lstm{i}"] = {
                "wx": dense_init(k1, d_in, 4 * self.d_hidden),
                "wh": dense_init(k2, self.d_hidden, 4 * self.d_hidden),
                "b": jnp.zeros((4 * self.d_hidden,)),
            }
            d_in = self.d_hidden
        return params

    def _lstm_layer(self, p, x):
        """x: (B, S, D) -> (B, S, H)."""
        b = x.shape[0]
        h0 = jnp.zeros((b, self.d_hidden))
        c0 = jnp.zeros((b, self.d_hidden))

        def step(carry, x_t):
            h, c = carry
            gates = x_t @ p["wx"] + h @ p["wh"] + p["b"]
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        (_, _), hs = jax.lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2)

    def hidden(self, params, tokens):
        x = params["embed"][tokens]
        for i in range(self.n_layers):
            x = self._lstm_layer(params[f"lstm{i}"], x)
        return x

    def logits(self, params, tokens):
        return self.hidden(params, tokens) @ params["w_out"] + params["b_out"]

    def loss(self, params, batch):
        logits = self.logits(params, batch["x"])
        total, per_example = _weighted_ce(logits, batch["y"],
                                          batch.get("weights"))
        return total, {"loss": total, "per_example_loss": per_example}

    def accuracy(self, params, batch):
        logits = self.logits(params, batch["x"])
        valid = batch["y"] != IGNORE
        correct = (jnp.argmax(logits, -1) == batch["y"]) & valid
        return jnp.sum(correct) / jnp.maximum(jnp.sum(valid), 1)

    def grad_features(self, params, batch):
        logits = self.logits(params, batch["x"])
        return _last_layer_grad_feature(logits, batch["y"], params["w_out"])
