from repro.models.model import IGNORE, Model  # noqa: F401
from repro.models.small import CharLSTM, LogisticRegression, SmallCNN  # noqa: F401
from repro.models.training import (  # noqa: F401
    make_eval_step,
    make_grad_fn,
    make_train_step,
)
