"""Unified model API across the six architecture families.

``Model(cfg)`` exposes:

  init(key)                          -> params (pytree)
  forward(params, batch)             -> (logits, aux, last_hidden)
  loss(params, batch)                -> (scalar, metrics)   [weighted CE]
  init_decode_state(batch, seq_len)  -> decode state (KV caches / SSM states)
  decode_step(params, state, token, pos) -> (logits, new state)
  input_specs(shape)                 -> jax.ShapeDtypeStruct stand-ins

Large stacks store per-layer params *stacked* on a leading axis and scan over
them; small/heterogeneous stacks (whisper, xlstm) use python loops.

Batch format (all int32 unless noted):
  tokens  (B, S)           labels (B, S)  (-100 = masked)
  weights (B,) float32     optional per-example coreset weights (FedCore δ/m)
  encoder_embeddings (B, S_enc, d) float  [audio family stub frontend]
  patch_embeddings   (B, P, d) float      [vlm family stub frontend]
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as attn
from repro.models import mamba2, moe, xlstm
from repro.models.layers import (dense_init, embed_init, init_mlp,
                                 init_rmsnorm, init_stacked, mlp, rmsnorm,
                                 sinusoidal_pos)

IGNORE = -100


# ---------------------------------------------------------------------------
# transformer layer (dense or moe)
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg),
        "ln2": init_rmsnorm(cfg.d_model),
    }
    if cfg.n_experts > 0:
        p["moe"] = moe.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if cross:
        p["ln_x"] = init_rmsnorm(cfg.d_model)
        p["xattn"] = attn.init_attention(ks[2], cfg, cross=True)
    return p


def _layer_fwd(p, cfg: ModelConfig, x, positions, *, causal=True,
               window=None, impl="chunked", use_rope=True,
               enc=None, enc_positions=None):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + attn.multihead_attention(p["attn"], cfg, h, positions,
                                     causal=causal, window=window, impl=impl,
                                     use_rope=use_rope)
    if enc is not None:
        h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + attn.multihead_attention(
            p["xattn"], cfg, h, positions, causal=False, impl=impl,
            kv_x=enc, kv_positions=enc_positions, use_rope=False)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts > 0:
        y, aux = moe.moe_ffn(p["moe"], cfg, h)
    else:
        y, aux = mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + y, aux


def _layer_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                  window=None, use_rope=True, enc_k=None, enc_v=None):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    y, cache_k, cache_v = attn.attention_decode(
        p["attn"], cfg, h, cache_k, cache_v, pos, window=window,
        use_rope=use_rope)
    x = x + y
    if enc_k is not None:
        h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + attn.cross_attention_decode(p["xattn"], cfg, h, enc_k, enc_v)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts > 0:
        y, _ = moe.moe_ffn(p["moe"], cfg, h)
    else:
        y = mlp(p["mlp"], h, cfg.act)
    return x + y, cache_k, cache_v


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family not in ("dense", "moe", "vlm", "audio", "ssm", "hybrid",
                              "xlstm"):
            raise ValueError(f"unknown family {cfg.family}")

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "ln_f": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["w_unembed"] = dense_init(ks[1], cfg.d_model,
                                             cfg.vocab_size, scale=0.02)

        if cfg.family in ("dense", "moe", "vlm"):
            params["layers"] = init_stacked(
                ks[2], cfg.n_layers, lambda k: _init_layer(k, cfg))
        elif cfg.family == "audio":
            enc_cfg = cfg.with_(act="gelu")
            params["enc_layers"] = [
                _init_layer(k, enc_cfg)
                for k in jax.random.split(ks[3], cfg.enc_layers)]
            params["enc_ln"] = init_rmsnorm(cfg.d_model)
            params["dec_layers"] = [
                _init_layer(k, cfg, cross=True)
                for k in jax.random.split(ks[2], cfg.n_layers)]
        elif cfg.family in ("ssm", "hybrid"):
            params["layers"] = init_stacked(
                ks[2], cfg.n_layers, lambda k: mamba2.init_mamba2(k, cfg))
            if cfg.family == "hybrid" and cfg.attn_every:
                params["shared_attn"] = _init_layer(ks[4], cfg)
                params["shared_in"] = dense_init(ks[5], 2 * cfg.d_model,
                                                 cfg.d_model)
        elif cfg.family == "xlstm":
            blocks = []
            for ch, k in zip(cfg.xlstm_pattern,
                             jax.random.split(ks[2], len(cfg.xlstm_pattern))):
                if ch == "m":
                    blocks.append(xlstm.init_mlstm(k, cfg))
                else:
                    blocks.append(xlstm.init_slstm(k, cfg))
            params["blocks"] = blocks
        return params

    def _unembed(self, params, h):
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["w_unembed"])
        return (h @ w.astype(h.dtype)).astype(jnp.float32)

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, *, impl: str = "chunked"):
        """Returns (logits (B,S,V) fp32, aux scalar, last_hidden (B,S,d))."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["embed"][tokens]
        prefix = 0

        if cfg.family == "vlm":
            patches = batch["patch_embeddings"].astype(x.dtype)
            prefix = patches.shape[1]
            x = jnp.concatenate([patches, x], axis=1)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "moe", "vlm"):
            def body(h, layer_p):
                h, a = _layer_fwd(layer_p, cfg, h, positions,
                                  window=cfg.attention_window, impl=impl)
                return h, a
            if cfg.remat:
                body = jax.checkpoint(body)
            x, auxs = jax.lax.scan(body, x, params["layers"])
            aux = jnp.sum(auxs)
        elif cfg.family == "audio":
            enc = batch["encoder_embeddings"].astype(x.dtype)
            enc = enc + sinusoidal_pos(enc.shape[1], cfg.d_model)
            enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
            enc_cfg = cfg.with_(act="gelu")
            for p in params["enc_layers"]:
                enc, _ = _layer_fwd(p, enc_cfg, enc, enc_pos, causal=False,
                                    impl=impl, use_rope=False)
            enc = rmsnorm(params["enc_ln"], enc, cfg.norm_eps)
            x = x + sinusoidal_pos(s, cfg.d_model)
            for p in params["dec_layers"]:
                x, _ = _layer_fwd(p, cfg, x, positions,
                                  window=cfg.attention_window, impl=impl,
                                  use_rope=False, enc=enc,
                                  enc_positions=enc_pos)
        elif cfg.family in ("ssm", "hybrid"):
            x = self._ssm_forward(params, x, positions, impl)
        elif cfg.family == "xlstm":
            for p, ch in zip(params["blocks"], cfg.xlstm_pattern):
                blk = xlstm.mlstm_block if ch == "m" else xlstm.slstm_block
                x, _ = blk(p, cfg, x)

        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        if prefix:
            x = x[:, prefix:]
        logits = self._unembed(params, x)
        return logits, aux, x

    def _ssm_forward(self, params, x, positions, impl):
        cfg = self.cfg
        emb = x
        if cfg.family == "hybrid" and cfg.attn_every:
            group = cfg.attn_every
            n_groups = cfg.n_layers // group
            tail = cfg.n_layers - n_groups * group
            stacked = params["layers"]
            head = jax.tree.map(
                lambda a: a[: n_groups * group].reshape(
                    (n_groups, group) + a.shape[1:]), stacked)

            def group_body(h, gp):
                def layer_body(hh, lp):
                    y, _ = mamba2.mamba2_block(lp, cfg, hh)
                    return hh + y, None
                h, _ = jax.lax.scan(layer_body, h, gp)
                # shared attention block with embedding skip (zamba2 concat)
                zin = jnp.concatenate([h, emb], axis=-1) @ params["shared_in"]
                y, _ = _layer_fwd(params["shared_attn"], cfg, zin, positions,
                                  window=cfg.attention_window, impl=impl)
                return h + y, None

            x, _ = jax.lax.scan(group_body, x, head)
            if tail:
                tail_p = jax.tree.map(lambda a: a[n_groups * group:], stacked)

                def layer_body(hh, lp):
                    y, _ = mamba2.mamba2_block(lp, cfg, hh)
                    return hh + y, None
                x, _ = jax.lax.scan(layer_body, x, tail_p)
        else:
            def layer_body(hh, lp):
                y, _ = mamba2.mamba2_block(lp, cfg, hh)
                return hh + y, None
            if cfg.remat:
                layer_body = jax.checkpoint(layer_body)
            x, _ = jax.lax.scan(layer_body, x, params["layers"])
        return x

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, impl: str = "chunked"):
        """Weighted next-token CE.  Returns (scalar, metrics dict)."""
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch, impl=impl)
        labels = batch["labels"]
        valid = (labels != IGNORE)
        safe = jnp.where(valid, labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        nll = nll * valid
        per_example = jnp.sum(nll, axis=-1) / jnp.maximum(
            jnp.sum(valid, axis=-1), 1)
        w = batch.get("weights")
        if w is None:
            w = jnp.ones_like(per_example)
        total = jnp.sum(per_example * w) / jnp.maximum(jnp.sum(w), 1e-9)
        loss = total + cfg.router_aux_coef * aux
        metrics = {"loss": total, "aux": aux,
                   "per_example_loss": per_example}
        return loss, metrics

    # -------------------------------------------------------- decode state
    def init_decode_state(self, params, batch: int, seq_len: int,
                          dtype=jnp.bfloat16, enc_embeddings=None):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return {"kv": attn.init_kv_cache(cfg, cfg.n_layers, batch,
                                             seq_len, dtype)}
        if cfg.family == "audio":
            st = {"kv": attn.init_kv_cache(cfg, cfg.n_layers, batch, seq_len,
                                           dtype)}
            # precompute encoder K/V for cross attention
            if enc_embeddings is None:
                s_enc = max(1, int(seq_len * cfg.enc_seq_frac))
                enc = jnp.zeros((batch, min(s_enc, 4096), cfg.d_model), dtype)
            else:
                enc = enc_embeddings
            enc = enc + sinusoidal_pos(enc.shape[1], cfg.d_model).astype(dtype)
            enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
            enc_cfg = cfg.with_(act="gelu")
            h = enc
            for p in params["enc_layers"]:
                h, _ = _layer_fwd(p, enc_cfg, h, enc_pos, causal=False,
                                  use_rope=False)
            h = rmsnorm(params["enc_ln"], h, cfg.norm_eps)
            eks, evs = [], []
            for p in params["dec_layers"]:
                hk, hd_ = cfg.n_kv_heads, cfg.d_head
                eks.append((h @ p["xattn"]["wk"].astype(h.dtype)).reshape(
                    batch, -1, hk, hd_))
                evs.append((h @ p["xattn"]["wv"].astype(h.dtype)).reshape(
                    batch, -1, hk, hd_))
            st["enc_k"] = jnp.stack(eks)
            st["enc_v"] = jnp.stack(evs)
            return st
        if cfg.family in ("ssm", "hybrid"):
            st = {"mamba": mamba2.init_mamba_state(cfg, batch, dtype)}
            st["mamba"] = mamba2.MambaState(
                ssm=jnp.zeros((cfg.n_layers,) + st["mamba"].ssm.shape, dtype),
                conv=jnp.zeros((cfg.n_layers,) + st["mamba"].conv.shape,
                               dtype))
            if cfg.family == "hybrid" and cfg.attn_every:
                n_groups = cfg.n_layers // cfg.attn_every
                st["kv"] = attn.init_kv_cache(cfg, n_groups, batch, seq_len,
                                              dtype)
            return st
        if cfg.family == "xlstm":
            sts = []
            for ch in cfg.xlstm_pattern:
                if ch == "m":
                    sts.append(xlstm.init_mlstm_state(cfg, batch, dtype))
                else:
                    sts.append(xlstm.init_slstm_state(cfg, batch, dtype))
            return {"blocks": sts}
        raise ValueError(cfg.family)

    # ---------------------------------------------------------- decode step
    def decode_step(self, params, state, token, pos):
        """token: (B, 1) int32; pos: scalar int32 -> (logits (B,1,V), state)."""
        cfg = self.cfg
        x = params["embed"][token]
        w = cfg.attention_window

        if cfg.family in ("dense", "moe", "vlm"):
            kv = state["kv"]

            def body(carry, inp):
                h = carry
                layer_p, ck, cv = inp
                h, ck, cv = _layer_decode(layer_p, cfg, h, ck, cv, pos,
                                          window=w)
                return h, (ck, cv)

            x, (nk, nv) = jax.lax.scan(body, x,
                                       (params["layers"], kv["k"], kv["v"]))
            state = {"kv": {"k": nk, "v": nv}}
        elif cfg.family == "audio":
            kv = state["kv"]
            x = x + _sin_pos_at(pos, cfg.d_model).astype(x.dtype)
            nks, nvs = [], []
            for i, p in enumerate(params["dec_layers"]):
                h, ck, cv = _layer_decode(
                    p, cfg, x, kv["k"][i], kv["v"][i], pos, window=w,
                    use_rope=False, enc_k=state["enc_k"][i],
                    enc_v=state["enc_v"][i])
                x = h
                nks.append(ck)
                nvs.append(cv)
            state = dict(state)
            state["kv"] = {"k": jnp.stack(nks), "v": jnp.stack(nvs)}
        elif cfg.family in ("ssm", "hybrid"):
            x, state = self._ssm_decode(params, state, x, pos)
        elif cfg.family == "xlstm":
            sts = []
            for p, ch, st in zip(params["blocks"], cfg.xlstm_pattern,
                                 state["blocks"]):
                blk = xlstm.mlstm_block if ch == "m" else xlstm.slstm_block
                x, st = blk(p, cfg, x, st, decode=True)
                sts.append(st)
            state = {"blocks": sts}

        h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return self._unembed(params, h), state

    def _ssm_decode(self, params, state, x, pos):
        cfg = self.cfg
        mst = state["mamba"]
        emb = x
        if cfg.family == "hybrid" and cfg.attn_every:
            group = cfg.attn_every
            n_groups = cfg.n_layers // group
            tail = cfg.n_layers - n_groups * group
            kv = state["kv"]
            new_ssm, new_conv = [], []
            nk, nv = [], []
            li = 0
            for g in range(n_groups):
                for _ in range(group):
                    lp = jax.tree.map(lambda a: a[li], params["layers"])
                    st = mamba2.MambaState(mst.ssm[li], mst.conv[li])
                    y, st = mamba2.mamba2_block(lp, cfg, x, st, decode=True)
                    x = x + y
                    new_ssm.append(st.ssm)
                    new_conv.append(st.conv)
                    li += 1
                zin = jnp.concatenate([x, emb], axis=-1) @ params["shared_in"]
                y, ck, cv = _layer_decode(params["shared_attn"], cfg, zin,
                                          kv["k"][g], kv["v"][g], pos,
                                          window=cfg.attention_window)
                x = x + y
                nk.append(ck)
                nv.append(cv)
            for _ in range(tail):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                st = mamba2.MambaState(mst.ssm[li], mst.conv[li])
                y, st = mamba2.mamba2_block(lp, cfg, x, st, decode=True)
                x = x + y
                new_ssm.append(st.ssm)
                new_conv.append(st.conv)
                li += 1
            state = {
                "mamba": mamba2.MambaState(jnp.stack(new_ssm),
                                           jnp.stack(new_conv)),
                "kv": {"k": jnp.stack(nk), "v": jnp.stack(nv)},
            }
        else:
            def body(carry, inp):
                h = carry
                lp, ssm_s, conv_s = inp
                y, st = mamba2.mamba2_block(
                    lp, cfg, h, mamba2.MambaState(ssm_s, conv_s), decode=True)
                return h + y, (st.ssm, st.conv)

            x, (ns, nc) = jax.lax.scan(body, x,
                                       (params["layers"], mst.ssm, mst.conv))
            state = {"mamba": mamba2.MambaState(ns, nc)}
        return x, state

    # ------------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16):
        """ShapeDtypeStruct stand-ins for every model input of this family."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, self._text_len(s)), i32),
                "labels": jax.ShapeDtypeStruct((b, self._text_len(s)), i32),
            }
            if shape.kind == "train":
                specs["weights"] = jax.ShapeDtypeStruct((b,), jnp.float32)
            if cfg.family == "audio":
                specs["encoder_embeddings"] = jax.ShapeDtypeStruct(
                    (b, s - self._text_len(s), cfg.d_model), dtype)
            if cfg.family == "vlm":
                specs["patch_embeddings"] = jax.ShapeDtypeStruct(
                    (b, self._n_patches(s), cfg.d_model), dtype)
            return specs
        # decode: one token + position
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    def _text_len(self, s: int) -> int:
        cfg = self.cfg
        if cfg.family == "audio":
            return s - int(s * cfg.enc_seq_frac)
        if cfg.family == "vlm":
            return s - self._n_patches(s)
        return s

    def _n_patches(self, s: int) -> int:
        return min(max(self.cfg.n_patches, 1), s // 4)


def _sin_pos_at(pos, d: int):
    """Sinusoidal positional embedding for a single (traced) position."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) if hasattr(pos, "astype") else float(pos)
    ang = ang / jnp.power(10000.0, 2 * i / d)
    out = jnp.zeros((d,), jnp.float32)
    out = out.at[0::2].set(jnp.sin(ang))
    out = out.at[1::2].set(jnp.cos(ang))
    return out
