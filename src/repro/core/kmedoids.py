"""k-medoids solvers for the FedCore coreset problem (Eq. 5).

Two implementations of the same (BUILD + PAM-objective SWAP) algorithm:

* ``kmedoids_numpy``  — host-side, loops until convergence.  Serves as the
  exactness oracle and matches the paper's FasterPAM usage (the swap step
  evaluates the full FasterPAM Δ(j, l) table each sweep, vectorized).
* ``kmedoids_jax``    — the TPU-native adaptation: identical dense math
  expressed as jnp ops inside ``lax.while_loop`` so selection runs on-device
  next to the gradient features (no host round-trip).  Data-dependent
  early-exit is preserved via the loop predicate.

Both take a precomputed (m, m) distance matrix ``D`` and a budget ``k`` and
return (medoid indices (k,), assignment (m,), objective scalar).

Swap Δ derivation (FasterPAM, Schubert & Rousseeuw 2021): with d1/d2 the
nearest/second-nearest medoid distance of each point and n(i) the nearest
medoid index,

    Δ(j, l) = Σ_i [ n(i)=l ? min(D[i,j], d2_i) − d1_i : min(D[i,j] − d1_i, 0) ]
            = A_j + B_{j,l}
    A_j     = Σ_i min(D[i,j] − d1_i, 0)
    B_{j,l} = Σ_{i: n(i)=l} ( min(D[i,j], d2_i) − d1_i − min(D[i,j] − d1_i, 0) )

so one sweep is two dense (m, m) reductions plus a segment-sum — MXU/VPU
friendly, no data-dependent gather loops.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e30


class KMedoidsResult(NamedTuple):
    medoids: jnp.ndarray     # (k,) int32 indices into the dataset
    assignment: jnp.ndarray  # (m,) int32 index into [0, k)
    weights: jnp.ndarray     # (k,) int32 cluster sizes (the paper's δ)
    objective: jnp.ndarray   # scalar Σ_i min_k D[i, medoid_k]


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def _build_numpy(D: np.ndarray, k: int) -> np.ndarray:
    m = D.shape[0]
    medoids = np.empty(k, np.int64)
    medoids[0] = np.argmin(D.sum(axis=0))
    d_near = D[:, medoids[0]].copy()
    for i in range(1, k):
        # cost of adding candidate j: sum(min(d_near, D[:, j]))
        cost = np.minimum(d_near[:, None], D).sum(axis=0)
        cost[medoids[:i]] = BIG
        medoids[i] = np.argmin(cost)
        d_near = np.minimum(d_near, D[:, medoids[i]])
    return medoids


def kmedoids_numpy(D: np.ndarray, k: int, max_sweeps: int = 100
                   ) -> KMedoidsResult:
    D = np.asarray(D, np.float64)
    m = D.shape[0]
    k = min(k, m)
    medoids = _build_numpy(D, k)

    for _ in range(max_sweeps):
        dm = D[:, medoids]                      # (m, k)
        order = np.argsort(dm, axis=1)
        n_idx = order[:, 0]                     # nearest medoid slot
        d1 = dm[np.arange(m), n_idx]
        d2 = dm[np.arange(m), order[:, 1]] if k > 1 else np.full(m, BIG)

        A = np.minimum(D - d1[:, None], 0.0).sum(axis=0)          # (m,)
        contrib = (np.minimum(D, d2[:, None]) - d1[:, None]
                   - np.minimum(D - d1[:, None], 0.0))            # (m_i, m_j)
        B = np.zeros((m, k))
        np.add.at(B.T, n_idx, contrib)  # B[j, l] = Σ_{i: n(i)=l} contrib[i, j]
        delta = A[:, None] + B                                    # (m_j, k)
        delta[medoids, :] = BIG  # cannot swap a medoid in
        j, l = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[j, l] >= -1e-12:
            break
        medoids[l] = j

    dm = D[:, medoids]
    assignment = np.argmin(dm, axis=1)
    weights = np.bincount(assignment, minlength=k)
    objective = dm[np.arange(m), assignment].sum()
    return KMedoidsResult(jnp.asarray(medoids, jnp.int32),
                          jnp.asarray(assignment, jnp.int32),
                          jnp.asarray(weights, jnp.int32),
                          jnp.asarray(objective, jnp.float32))


# ---------------------------------------------------------------------------
# JAX on-device solver
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "max_sweeps"))
def kmedoids_jax(D: jnp.ndarray, k: int, max_sweeps: int = 50
                 ) -> KMedoidsResult:
    """On-device BUILD+SWAP on an unpadded instance — the all-valid special
    case of ``kmedoids_masked`` (one solver, one copy of the Δ-table math;
    an all-True mask multiplies every reduction by exactly 1.0, so results
    are bitwise those of an unmasked implementation)."""
    return kmedoids_masked(D, jnp.ones((D.shape[0],), bool), k,
                           max_sweeps=max_sweeps)


@partial(jax.jit, static_argnames=("k", "max_sweeps"))
def kmedoids_masked(D: jnp.ndarray, valid: jnp.ndarray, k: int,
                    max_sweeps: int = 50) -> KMedoidsResult:
    """``kmedoids_jax`` on a *padded* instance.

    ``D`` is (M, M) where only the rows/cols with ``valid[i]`` True are real
    samples; padded entries may hold arbitrary finite values.  Invalid points
    are never selected as medoids, contribute nothing to any objective or Δ
    sum, and get assignment −1 / weight 0.  With ``valid`` all-True this is
    exactly ``kmedoids_jax`` (the unpadded solver) — the fleet engine relies
    on that equivalence to vmap one solve per client over a cohort stack.

    Callers must guarantee ``k <= valid.sum()`` (not checkable under jit).
    """
    D = D.astype(jnp.float32)
    m = D.shape[0]
    k = min(k, m)
    vf = valid.astype(jnp.float32)          # (m,) 1.0 on real samples
    invalid = ~valid.astype(bool)

    # ---- BUILD (greedy adds; sums masked by vf, invalid candidates BIG) ---
    cost0 = jnp.sum(D * vf[:, None], axis=0)
    cost0 = jnp.where(invalid, BIG, cost0)
    first = jnp.argmin(cost0).astype(jnp.int32)
    d_near0 = D[:, first]

    def build_step(carry, _):
        d_near, chosen_mask = carry
        cost = jnp.sum(jnp.minimum(d_near[:, None], D) * vf[:, None], axis=0)
        cost = jnp.where(chosen_mask | invalid, BIG, cost)
        nxt = jnp.argmin(cost).astype(jnp.int32)
        d_near = jnp.minimum(d_near, D[:, nxt])
        chosen_mask = chosen_mask.at[nxt].set(True)
        return (d_near, chosen_mask), nxt

    mask0 = jnp.zeros((m,), bool).at[first].set(True)
    (_, _), rest = jax.lax.scan(build_step, (d_near0, mask0), None,
                                length=k - 1)
    medoids0 = jnp.concatenate([first[None], rest]) if k > 1 else first[None]

    # ---- SWAP sweeps (FasterPAM Δ table; all reductions masked by vf) -----
    def sweep(state):
        medoids, _, it = state
        dm = D[:, medoids]                                        # (m, k)
        if k > 1:
            top2_val, top2_idx = jax.lax.top_k(-dm, 2)
            d1 = -top2_val[:, 0]
            d2 = -top2_val[:, 1]
            n_idx = top2_idx[:, 0]
        else:
            d1 = dm[:, 0]
            d2 = jnp.full((m,), BIG)
            n_idx = jnp.zeros((m,), jnp.int32)

        shift = jnp.minimum(D - d1[:, None], 0.0) * vf[:, None]
        A = jnp.sum(shift, axis=0)                                # (m_j,)
        contrib = ((jnp.minimum(D, d2[:, None]) - d1[:, None]) * vf[:, None]
                   - shift)
        onehot = jax.nn.one_hot(n_idx, k, dtype=contrib.dtype)
        B = jnp.einsum("ij,il->jl", contrib, onehot)              # (m_j, k)
        delta = A[:, None] + B
        is_medoid = jnp.zeros((m,), bool).at[medoids].set(True)
        delta = jnp.where((is_medoid | invalid)[:, None], BIG, delta)
        flat = jnp.argmin(delta)
        j, l = flat // k, flat % k
        best = delta.reshape(-1)[flat]
        medoids = jnp.where(best < -1e-6, medoids.at[l].set(j.astype(
            jnp.int32)), medoids)
        return medoids, best, it + 1

    def cond(state):
        _, best, it = state
        return (best < -1e-6) & (it < max_sweeps)

    state = (medoids0, jnp.asarray(-jnp.inf, jnp.float32),
             jnp.asarray(0, jnp.int32))
    medoids, _, _ = jax.lax.while_loop(cond, sweep, state)

    dm = D[:, medoids]
    assignment = jnp.where(valid, jnp.argmin(dm, axis=1), -1).astype(jnp.int32)
    weights = jnp.sum(jax.nn.one_hot(assignment, k, dtype=jnp.int32), axis=0)
    objective = jnp.sum(jnp.min(dm, axis=1) * vf)
    return KMedoidsResult(medoids.astype(jnp.int32), assignment, weights,
                          objective)


@partial(jax.jit, static_argnames=("k", "max_sweeps"))
def kmedoids_batched(D: jnp.ndarray, valid: jnp.ndarray, k: int,
                     max_sweeps: int = 50) -> KMedoidsResult:
    """One masked k-medoids solve per client over a cohort stack.

    D: (C, M, M) distance stack; valid: (C, M) sample masks; static ``k``
    shared across the cohort (the fleet engine groups clients by quantized
    budget).  Returns a ``KMedoidsResult`` of stacked fields.  The batched
    ``while_loop`` runs until every client's swap phase converges; frozen
    lanes keep their converged medoids, so each lane's result equals its
    standalone ``kmedoids_masked`` solve.
    """
    return jax.vmap(lambda d, v: kmedoids_masked(d, v, k, max_sweeps))(
        D, valid)


def pairwise_sq_dists(x: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    """(m, d) -> (m, m) squared Euclidean distances.

    ``use_kernel=True`` routes through the Pallas TPU kernel
    (``repro.kernels.ops.pairwise_l2``); default is the jnp formulation
    (identical math, runs on any backend).
    """
    if use_kernel:
        from repro.kernels.ops import pairwise_l2
        d = pairwise_l2(x, squared=True)
    else:
        sq = jnp.sum(jnp.square(x), axis=-1)
        d = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    # exact zeros on the self-distance diagonal (numerical cancellation)
    m = d.shape[0]
    return d * (1.0 - jnp.eye(m, dtype=d.dtype))
