"""k-medoids solvers for the FedCore coreset problem (Eq. 5).

Two implementations of the same (BUILD + PAM-objective SWAP) algorithm:

* ``kmedoids_numpy``  — host-side, loops until convergence.  Serves as the
  exactness oracle and matches the paper's FasterPAM usage (the swap step
  evaluates the full FasterPAM Δ(j, l) table each sweep, vectorized).
* ``kmedoids_batched`` — the TPU-native adaptation and the fleet engine's
  hot path: identical dense math over a whole (C, M, M) cohort distance
  stack inside one ``lax.while_loop`` (data-dependent early exit via the
  any-lane-still-improving predicate; converged lanes are fixed points of
  the sweep, so each lane's result equals its standalone solve).
  ``kmedoids_masked`` / ``kmedoids_jax`` are the C = 1 (and additionally
  all-valid) views of the same solver — one copy of the Δ-table math.

Both take precomputed distances and a budget ``k`` and return (medoid
indices, assignment, cluster-size weights, objective).

Swap Δ derivation (FasterPAM, Schubert & Rousseeuw 2021): with d1/d2 the
nearest/second-nearest medoid distance of each point and n(i) the nearest
medoid index,

    Δ(j, l) = Σ_i [ n(i)=l ? min(D[i,j], d2_i) − d1_i : min(D[i,j] − d1_i, 0) ]
            = A_j + B_{j,l}
    A_j     = Σ_i ( min(D[i,j], d1_i) − d1_i )
    B_{j,l} = Σ_{i: n(i)=l} ( clip(D[i,j], d1_i, d2_i) − d1_i )

(the clip form collapses the textbook ``min(D, d2) − d1 − min(D − d1, 0)``
case split — bitwise equal for d1 ≤ d2).  One sweep is therefore a single
pass over D producing a dense (m,) + (m, k) pair; the fused Pallas kernel
(``repro.kernels.kmedoids_pallas.delta_sweep_pallas``) computes both
reductions tile-by-tile, and ``repro.kernels.ref.kmedoids_delta_sweep_ref``
is the identical-math jnp fallback.  ``legacy_sweep=True`` keeps the
pre-fusion ``minimum``/``one_hot``/``einsum`` chain (3+ full O(M²) passes
per sweep) as the measured A/B baseline for
``benchmarks/fleet_sweep.py``'s selection-phase breakdown.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e30


class KMedoidsResult(NamedTuple):
    medoids: jnp.ndarray     # (k,) int32 indices into the dataset
    assignment: jnp.ndarray  # (m,) int32 index into [0, k)
    weights: jnp.ndarray     # (k,) int32 cluster sizes (the paper's δ)
    objective: jnp.ndarray   # scalar Σ_i min_k D[i, medoid_k]


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def _build_numpy(D: np.ndarray, k: int) -> np.ndarray:
    m = D.shape[0]
    medoids = np.empty(k, np.int64)
    medoids[0] = np.argmin(D.sum(axis=0))
    d_near = D[:, medoids[0]].copy()
    for i in range(1, k):
        # cost of adding candidate j: sum(min(d_near, D[:, j]))
        cost = np.minimum(d_near[:, None], D).sum(axis=0)
        cost[medoids[:i]] = BIG
        medoids[i] = np.argmin(cost)
        d_near = np.minimum(d_near, D[:, medoids[i]])
    return medoids


def kmedoids_numpy(D: np.ndarray, k: int, max_sweeps: int = 100
                   ) -> KMedoidsResult:
    D = np.asarray(D, np.float64)
    m = D.shape[0]
    k = min(k, m)
    medoids = _build_numpy(D, k)

    for _ in range(max_sweeps):
        dm = D[:, medoids]                      # (m, k)
        order = np.argsort(dm, axis=1)
        n_idx = order[:, 0]                     # nearest medoid slot
        d1 = dm[np.arange(m), n_idx]
        d2 = dm[np.arange(m), order[:, 1]] if k > 1 else np.full(m, BIG)

        A = np.minimum(D - d1[:, None], 0.0).sum(axis=0)          # (m,)
        contrib = (np.minimum(D, d2[:, None]) - d1[:, None]
                   - np.minimum(D - d1[:, None], 0.0))            # (m_i, m_j)
        B = np.zeros((m, k))
        np.add.at(B.T, n_idx, contrib)  # B[j, l] = Σ_{i: n(i)=l} contrib[i, j]
        delta = A[:, None] + B                                    # (m_j, k)
        delta[medoids, :] = BIG  # cannot swap a medoid in
        j, l = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[j, l] >= -1e-12:
            break
        medoids[l] = j

    dm = D[:, medoids]
    assignment = np.argmin(dm, axis=1)
    weights = np.bincount(assignment, minlength=k)
    objective = dm[np.arange(m), assignment].sum()
    return KMedoidsResult(jnp.asarray(medoids, jnp.int32),
                          jnp.asarray(assignment, jnp.int32),
                          jnp.asarray(weights, jnp.int32),
                          jnp.asarray(objective, jnp.float32))


# ---------------------------------------------------------------------------
# JAX on-device solver (natively batched; masked lanes)
# ---------------------------------------------------------------------------

def _take_col(D: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """D (C, M, M), idx (C,) -> (C, M) = D[c, :, idx[c]]."""
    return jnp.take_along_axis(D, idx[:, None, None], axis=2)[..., 0]


@partial(jax.jit, static_argnames=("k", "max_sweeps", "use_kernel",
                                   "legacy_sweep"))
def _kmedoids_batched(D: jnp.ndarray, valid: jnp.ndarray, k: int,
                      max_sweeps: int, use_kernel: bool,
                      legacy_sweep: bool) -> KMedoidsResult:
    from repro.kernels.ops import kmedoids_build_cost, kmedoids_delta_sweep

    D = D.astype(jnp.float32)
    c, m = D.shape[0], D.shape[1]
    vf = valid.astype(jnp.float32)          # (C, M) 1.0 on real samples
    invalid = ~valid.astype(bool)
    iota_m = jnp.arange(m, dtype=jnp.int32)

    # ---- BUILD (greedy adds; sums masked by vf, invalid candidates BIG) ---
    def add_cost(d_near):
        # Σ_i min(d_near_i, D_ij)·vf_i — the fused one-pass reduction
        # (d_near = +BIG for the first pick reduces it to the column sum)
        return kmedoids_build_cost(D, d_near, vf, use_kernel=use_kernel)

    cost0 = jnp.where(invalid, BIG, add_cost(jnp.full((c, m), BIG,
                                                      jnp.float32)))
    first = jnp.argmin(cost0, axis=1).astype(jnp.int32)            # (C,)
    d_near0 = _take_col(D, first)

    def build_step(carry, _):
        d_near, chosen = carry
        cost = jnp.where(chosen | invalid, BIG, add_cost(d_near))
        nxt = jnp.argmin(cost, axis=1).astype(jnp.int32)
        d_near = jnp.minimum(d_near, _take_col(D, nxt))
        chosen = chosen | (iota_m[None] == nxt[:, None])
        return (d_near, chosen), nxt

    mask0 = iota_m[None] == first[:, None]
    if k > 1:
        (_, _), rest = jax.lax.scan(build_step, (d_near0, mask0), None,
                                    length=k - 1)
        medoids0 = jnp.concatenate([first[:, None], rest.T], axis=1)
    else:
        medoids0 = first[:, None]

    # ---- SWAP sweeps (FasterPAM Δ table; all reductions masked by vf) -----
    def sweep(state):
        medoids, _, it = state
        dm = jnp.take_along_axis(D, medoids[:, None, :], axis=2)  # (C, M, k)
        if legacy_sweep:
            # pre-fusion baseline: top_k stats + 3-pass minimum/one_hot/
            # einsum chain (kept for the selection-phase A/B benchmark)
            if k > 1:
                top2_val, top2_idx = jax.lax.top_k(-dm, 2)
                d1, d2 = -top2_val[..., 0], -top2_val[..., 1]
                n_idx = top2_idx[..., 0]
            else:
                d1 = dm[..., 0]
                d2 = jnp.full((c, m), BIG)
                n_idx = jnp.zeros((c, m), jnp.int32)
            shift = jnp.minimum(D - d1[..., None], 0.0) * vf[..., None]
            A = jnp.sum(shift, axis=1)
            contrib = ((jnp.minimum(D, d2[..., None]) - d1[..., None])
                       * vf[..., None] - shift)
            onehot = jax.nn.one_hot(n_idx, k, dtype=contrib.dtype)
            B = jnp.einsum("cij,cil->cjl", contrib, onehot)
        else:
            d1 = jnp.min(dm, axis=-1)
            n_idx = jnp.argmin(dm, axis=-1).astype(jnp.int32)
            n_onehot = (jnp.arange(k, dtype=jnp.int32)[None, None]
                        == n_idx[..., None])
            # second-nearest = min with the nearest slot masked out
            # (k = 1 masks everything, giving the conventional d2 = BIG)
            d2 = jnp.min(jnp.where(n_onehot, BIG, dm), axis=-1)
            A, B = kmedoids_delta_sweep(D, d1, d2, vf,
                                        n_onehot.astype(D.dtype),
                                        use_kernel=use_kernel)
        delta = A[..., None] + B                                  # (C, M, k)
        is_medoid = (iota_m[None, :, None] == medoids[:, None, :]).any(-1)
        delta = jnp.where((is_medoid | invalid)[..., None], BIG, delta)
        flat = jnp.argmin(delta.reshape(c, m * k), axis=1)
        best = jnp.take_along_axis(delta.reshape(c, m * k), flat[:, None],
                                   axis=1)[:, 0]
        j = (flat // k).astype(jnp.int32)
        l = (flat % k).astype(jnp.int32)
        swapped = jnp.where(jnp.arange(k, dtype=jnp.int32)[None]
                            == l[:, None], j[:, None], medoids)
        medoids = jnp.where((best < -1e-6)[:, None], swapped, medoids)
        return medoids, best, it + 1

    def cond(state):
        _, best, it = state
        return jnp.any(best < -1e-6) & (it < max_sweeps)

    state = (medoids0.astype(jnp.int32),
             jnp.full((c,), -jnp.inf, jnp.float32),
             jnp.asarray(0, jnp.int32))
    medoids, _, _ = jax.lax.while_loop(cond, sweep, state)

    dm = jnp.take_along_axis(D, medoids[:, None, :], axis=2)
    assignment = jnp.where(valid, jnp.argmin(dm, axis=-1),
                           -1).astype(jnp.int32)
    weights = jnp.sum(jax.nn.one_hot(assignment, k, dtype=jnp.int32), axis=1)
    objective = jnp.sum(jnp.min(dm, axis=-1) * vf, axis=1)
    return KMedoidsResult(medoids.astype(jnp.int32), assignment, weights,
                          objective)


def kmedoids_batched(D: jnp.ndarray, valid: jnp.ndarray, k: int,
                     max_sweeps: int = 50,
                     use_kernel: Optional[bool] = None,
                     legacy_sweep: bool = False) -> KMedoidsResult:
    """One masked k-medoids solve per client over a cohort stack.

    D: (C, M, M) distance stack; valid: (C, M) sample masks; static ``k``
    shared across the cohort (the fleet engine groups clients by quantized
    budget).  Only rows/cols with ``valid[c, i]`` True are real samples;
    padded entries may hold arbitrary finite values, are never selected as
    medoids, contribute nothing to any objective or Δ sum, and get
    assignment −1 / weight 0.  Callers must guarantee
    ``k <= valid[c].sum()`` per lane (not checkable under jit).

    Returns a ``KMedoidsResult`` of stacked fields.  The batched
    ``while_loop`` runs until every lane's swap phase converges; converged
    lanes are fixed points of the sweep (no Δ < −1e−6 remains, so the
    masked update is the identity), hence each lane's result equals its
    standalone ``kmedoids_masked`` solve.

    ``use_kernel`` is the tri-state Pallas switch (None = auto: kernels on
    TPU, jnp elsewhere — see ``repro.kernels.ops.resolve_use_kernel``);
    ``legacy_sweep`` selects the pre-fusion sweep chain (A/B baseline).
    """
    from repro.kernels.ops import resolve_use_kernel
    return _kmedoids_batched(D, valid, min(int(k), D.shape[-1]),
                             int(max_sweeps), resolve_use_kernel(use_kernel),
                             bool(legacy_sweep))


# ---------------------------------------------------------------------------
# distance-free solver: same BUILD+SWAP control flow, D never materialized
# ---------------------------------------------------------------------------

def _col_dists(xf: jnp.ndarray, sq: jnp.ndarray,
               idx: jnp.ndarray) -> jnp.ndarray:
    """(C, M) distances of every row to column idx[c], rebuilt from feats.

    Exact zero pinned at the self index (the ``zero_self_diag`` contract,
    one column at a time)."""
    m = xf.shape[1]
    xc = jnp.take_along_axis(xf, idx[:, None, None], axis=1)   # (C, 1, F)
    sqc = jnp.take_along_axis(sq, idx[:, None], axis=1)        # (C, 1)
    d2 = sq + sqc - 2.0 * jnp.sum(xf * xc, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    return jnp.where(jnp.arange(m, dtype=jnp.int32)[None] == idx[:, None],
                     0.0, d)


def _medoid_dists(xf: jnp.ndarray, sq: jnp.ndarray,
                  medoids: jnp.ndarray) -> jnp.ndarray:
    """(C, M, k) distances to the current medoid set, rebuilt from feats."""
    m = xf.shape[1]
    xm = jnp.take_along_axis(xf, medoids[:, :, None], axis=1)  # (C, k, F)
    sqm = jnp.take_along_axis(sq, medoids, axis=1)             # (C, k)
    d2 = (sq[..., None] + sqm[:, None, :]
          - 2.0 * jnp.einsum("cmf,ckf->cmk", xf, xm))
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    self_mask = (jnp.arange(m, dtype=jnp.int32)[None, :, None]
                 == medoids[:, None, :])
    return jnp.where(self_mask, 0.0, d)


@partial(jax.jit, static_argnames=("k", "max_sweeps", "use_kernel"))
def _kmedoids_batched_from_feats(feats: jnp.ndarray, valid: jnp.ndarray,
                                 k: int, max_sweeps: int,
                                 use_kernel: bool) -> KMedoidsResult:
    from repro.kernels.ops import (kmedoids_build_cost_from_feats,
                                   kmedoids_delta_sweep_from_feats)

    xf = feats.astype(jnp.float32)
    c, m = xf.shape[0], xf.shape[1]
    sq = jnp.sum(xf * xf, axis=-1)          # (C, M) squared norms, once
    vf = valid.astype(jnp.float32)
    invalid = ~valid.astype(bool)
    iota_m = jnp.arange(m, dtype=jnp.int32)

    # ---- BUILD: identical greedy to _kmedoids_batched; the add-cost
    # reduction consumes feature tiles and the per-pick d_near update is a
    # single rebuilt column — never a (C, M, M) stack.
    def add_cost(d_near):
        return kmedoids_build_cost_from_feats(xf, d_near, vf,
                                              use_kernel=use_kernel)

    cost0 = jnp.where(invalid, BIG, add_cost(jnp.full((c, m), BIG,
                                                      jnp.float32)))
    first = jnp.argmin(cost0, axis=1).astype(jnp.int32)            # (C,)
    d_near0 = _col_dists(xf, sq, first)

    def build_step(carry, _):
        d_near, chosen = carry
        cost = jnp.where(chosen | invalid, BIG, add_cost(d_near))
        nxt = jnp.argmin(cost, axis=1).astype(jnp.int32)
        d_near = jnp.minimum(d_near, _col_dists(xf, sq, nxt))
        chosen = chosen | (iota_m[None] == nxt[:, None])
        return (d_near, chosen), nxt

    mask0 = iota_m[None] == first[:, None]
    if k > 1:
        (_, _), rest = jax.lax.scan(build_step, (d_near0, mask0), None,
                                    length=k - 1)
        medoids0 = jnp.concatenate([first[:, None], rest.T], axis=1)
    else:
        medoids0 = first[:, None]

    # ---- SWAP: d1/d2/n(i) come from the O(C·M·k) medoid-distance slab;
    # the Δ reductions stream feature tiles.
    def sweep(state):
        medoids, _, it = state
        dm = _medoid_dists(xf, sq, medoids)                       # (C, M, k)
        d1 = jnp.min(dm, axis=-1)
        n_idx = jnp.argmin(dm, axis=-1).astype(jnp.int32)
        n_onehot = (jnp.arange(k, dtype=jnp.int32)[None, None]
                    == n_idx[..., None])
        d2 = jnp.min(jnp.where(n_onehot, BIG, dm), axis=-1)
        A, B = kmedoids_delta_sweep_from_feats(xf, d1, d2, vf,
                                               n_onehot.astype(jnp.float32),
                                               use_kernel=use_kernel)
        delta = A[..., None] + B                                  # (C, M, k)
        is_medoid = (iota_m[None, :, None] == medoids[:, None, :]).any(-1)
        delta = jnp.where((is_medoid | invalid)[..., None], BIG, delta)
        flat = jnp.argmin(delta.reshape(c, m * k), axis=1)
        best = jnp.take_along_axis(delta.reshape(c, m * k), flat[:, None],
                                   axis=1)[:, 0]
        j = (flat // k).astype(jnp.int32)
        l = (flat % k).astype(jnp.int32)
        swapped = jnp.where(jnp.arange(k, dtype=jnp.int32)[None]
                            == l[:, None], j[:, None], medoids)
        medoids = jnp.where((best < -1e-6)[:, None], swapped, medoids)
        return medoids, best, it + 1

    def cond(state):
        _, best, it = state
        return jnp.any(best < -1e-6) & (it < max_sweeps)

    state = (medoids0.astype(jnp.int32),
             jnp.full((c,), -jnp.inf, jnp.float32),
             jnp.asarray(0, jnp.int32))
    medoids, _, _ = jax.lax.while_loop(cond, sweep, state)

    dm = _medoid_dists(xf, sq, medoids)
    assignment = jnp.where(valid, jnp.argmin(dm, axis=-1),
                           -1).astype(jnp.int32)
    weights = jnp.sum(jax.nn.one_hot(assignment, k, dtype=jnp.int32), axis=1)
    objective = jnp.sum(jnp.min(dm, axis=-1) * vf, axis=1)
    return KMedoidsResult(medoids.astype(jnp.int32), assignment, weights,
                          objective)


def kmedoids_batched_from_feats(feats: jnp.ndarray, valid: jnp.ndarray,
                                k: int, max_sweeps: int = 50,
                                use_kernel: Optional[bool] = None
                                ) -> KMedoidsResult:
    """Distance-free twin of :func:`kmedoids_batched`.

    feats: (C, M, F) per-client feature stack; valid: (C, M) masks.  Same
    BUILD+SWAP control flow and masking contract, but the (C, M, M)
    distance stack is never materialized: the BUILD add-cost and Δ-sweep
    reductions consume feature tiles (Pallas kernels or the chunked jnp
    fallback under the tri-state ``use_kernel``), and the only per-round
    distance tensors are O(C·M) columns and the O(C·M·k) medoid slab.
    Peak selection memory drops from O(C·M²) to O(C·M·(F + k)) — per-
    client M in the thousands instead of hundreds.

    Padded lanes (valid False) carry zero feature rows, which are
    mutually at distance 0; the from-feats reductions mask those
    candidates to +BIG **in-kernel** so they can never tie-win a medoid
    election over a valid point.
    """
    from repro.kernels.ops import resolve_use_kernel
    return _kmedoids_batched_from_feats(feats, valid,
                                        min(int(k), feats.shape[1]),
                                        int(max_sweeps),
                                        resolve_use_kernel(use_kernel))


def kmedoids_masked(D: jnp.ndarray, valid: jnp.ndarray, k: int,
                    max_sweeps: int = 50,
                    use_kernel: Optional[bool] = None) -> KMedoidsResult:
    """Masked solve of a single *padded* instance — the C = 1 view of
    ``kmedoids_batched`` (one solver, one copy of the Δ-table math)."""
    res = kmedoids_batched(D[None], valid[None], k, max_sweeps, use_kernel)
    return KMedoidsResult(res.medoids[0], res.assignment[0], res.weights[0],
                          res.objective[0])


def kmedoids_jax(D: jnp.ndarray, k: int, max_sweeps: int = 50,
                 use_kernel: Optional[bool] = None) -> KMedoidsResult:
    """On-device BUILD+SWAP on an unpadded instance — the all-valid special
    case of ``kmedoids_masked`` (an all-True mask multiplies every
    reduction by exactly 1.0, so results are bitwise those of an unmasked
    implementation)."""
    return kmedoids_masked(D, jnp.ones((D.shape[0],), bool), k,
                           max_sweeps=max_sweeps, use_kernel=use_kernel)


def pairwise_sq_dists(x: jnp.ndarray,
                      use_kernel: Optional[bool] = None) -> jnp.ndarray:
    """(m, d) -> (m, m) squared Euclidean distances.

    ``use_kernel`` is the tri-state Pallas switch: True routes through the
    MXU-tiled kernel (``repro.kernels.ops.pairwise_l2``), False the jnp
    formulation (identical math, any backend), None auto-selects by
    backend.  Either way the self-distance diagonal is pinned to exact
    zeros by the shared ``zero_self_diag`` epilogue the pairwise wrappers
    own.
    """
    from repro.kernels.ops import (pairwise_l2, resolve_use_kernel,
                                   zero_self_diag)
    if resolve_use_kernel(use_kernel):
        return pairwise_l2(x, squared=True, zero_diag=True)
    sq = jnp.sum(jnp.square(x), axis=-1)
    d = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    return zero_self_diag(d)
