"""FedCore coreset construction (paper §3.2, §4.2, §4.3).

The coreset problem (Eq. 2) is upper-bounded (Eq. 3-4) and solved as a
k-medoids instance (Eq. 5) over *gradient features*:

  * convex models      -> input-space features  (d̃ⱼₖ = ‖xⱼ − xₖ‖)
  * deep networks      -> last-layer gradient features
                          (d̂ⱼₖ = ‖∂Lⱼ/∂zⱼ − ∂Lₖ/∂zₖ‖, §4.3)

The budget (§4.2): the first epoch of a round runs the full set (mⁱ samples,
producing the features); the remaining E−1 epochs run the coreset, so

    bⁱ = ⌊(cⁱ·τ − mⁱ) / (E − 1)⌋.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmedoids import (KMedoidsResult, kmedoids_batched,
                                 kmedoids_batched_from_feats, kmedoids_jax,
                                 kmedoids_numpy, pairwise_sq_dists)


class Coreset(NamedTuple):
    indices: jnp.ndarray   # (k,) int32 — selected sample indices Sⁱ
    weights: jnp.ndarray   # (k,) float32 — δⁱ (cluster sizes)
    objective: jnp.ndarray  # scalar — the Eq.(5) k-medoids objective
    assignment: jnp.ndarray  # (m,) int32 — Φⁱ mapping (by medoid slot)


def coreset_budget(m: int, capability: float, deadline: float,
                   epochs: int, cost=None) -> int:
    """bⁱ = ⌊(cⁱτ − mⁱ·κ)/(κ(E−1))⌋ clipped to [1, mⁱ] (paper §4.2).

    ``cost`` is an optional ``repro.fed.cost.WorkloadCostModel`` (or a
    per-sample cost scalar): the deadline buys cⁱτ *cost units*, of which
    each sample-visit consumes κ.  ``cost=None`` is the legacy
    samples-cost-1.0 mode — byte-identical to the pre-cost formula.
    The arithmetic itself lives in ``repro.fed.cost`` (imported lazily:
    ``repro.fed`` imports this module at package-init time).
    """
    from repro.fed.cost import resolve_cost
    return resolve_cost(cost).budget(m, capability, deadline, epochs)


def needs_coreset(m: int, capability: float, deadline: float,
                  epochs: int, cost=None) -> bool:
    """Alg. 1 line 6: full-set training iff E·mⁱ·κ ≤ cⁱτ (see
    ``coreset_budget`` for the ``cost`` parameter)."""
    from repro.fed.cost import resolve_cost
    return resolve_cost(cost).needs_coreset(m, capability, deadline, epochs)


def build_coreset(features: jnp.ndarray, budget: int, *,
                  backend: str = "jax", use_kernel: Optional[bool] = None,
                  max_sweeps: int = 50,
                  projection_dim: Optional[int] = None) -> Coreset:
    """Solve Eq.(5) on the given per-sample feature matrix (m, F).

    Distances are Euclidean in feature space — exactly d̃ (input features) or
    d̂ (last-layer gradient features) depending on what the caller passes.
    ``use_kernel`` is the tri-state Pallas switch (None = auto: kernels on
    supported backends, jnp fallback otherwise) for both the pairwise
    distances and the fused k-medoids reductions.  ``projection_dim``
    applies a JL random projection first (§Perf H3).
    """
    m = features.shape[0]
    budget = min(budget, m)
    if projection_dim is not None:
        from repro.core.gradients import project_features
        features = project_features(features, projection_dim)
    D2 = pairwise_sq_dists(features, use_kernel=use_kernel)
    D = jnp.sqrt(jnp.maximum(D2, 0.0))
    if backend == "numpy":
        res = kmedoids_numpy(np.asarray(D), budget, max_sweeps=max_sweeps)
    else:
        res = kmedoids_jax(D, budget, max_sweeps=max_sweeps,
                           use_kernel=use_kernel)
    return Coreset(indices=res.medoids,
                   weights=res.weights.astype(jnp.float32),
                   objective=res.objective,
                   assignment=res.assignment)


def build_coreset_batched(features: jnp.ndarray, valid: jnp.ndarray,
                          budget: int, *, use_kernel: Optional[bool] = None,
                          max_sweeps: int = 50,
                          distance_free: bool = True,
                          materialize_below: int = 256) -> Coreset:
    """One coreset per client over a padded cohort stack (fleet engine).

    features: (C, M, F) per-client gradient features, rows with
    ``valid[c, i]`` False being padding; ``budget`` is the static per-client
    k (clients are grouped by quantized budget upstream).  Returns a
    ``Coreset`` of stacked fields — indices (C, k), weights (C, k), etc.
    Each lane solves exactly the instance ``build_coreset`` would solve on
    that client's unpadded features.  ``use_kernel`` (tri-state, None =
    auto by backend) routes the distance/reduction math through the Pallas
    kernels.

    ``distance_free`` (default on) solves straight from the feature stack
    — the (C, M, M) distance tensor is never materialized, so peak
    selection memory is O(C·M·F) instead of O(C·M²) and per-client M
    scales to the thousands.  ``distance_free=False`` keeps the
    materializing pairwise + D-input solver as the measured A/B baseline
    (``benchmarks/fleet_sweep.py --selection-memory``).

    ``materialize_below`` is the adaptive cutover: below it the (C, M, M)
    stack is a few MB and recomputing distances every BUILD step /
    Δ-sweep costs more than it saves (streaming trades O(k·C·M²·F)
    recompute FLOPs for O(C·M²) memory), so ``distance_free=True``
    materializes anyway — selection at typical fleet M is bit-identical
    to the D-input path.  At ``M >= materialize_below`` it streams.
    Pass ``materialize_below=0`` to force streaming at any size (the
    parity tests do).
    """
    from repro.kernels.ops import pairwise_l2_batched, resolve_use_kernel
    c, m, _ = features.shape
    budget = min(budget, m)
    uk = resolve_use_kernel(use_kernel)
    if distance_free and m >= materialize_below:
        # padded rows must be zero features: mutually-zero distances are
        # masked in-kernel (+BIG candidates), valid rows are untouched
        feats = features * valid.astype(features.dtype)[..., None]
        res = kmedoids_batched_from_feats(feats, valid, budget,
                                          max_sweeps=max_sweeps,
                                          use_kernel=uk)
    else:
        # zero_diag: the pairwise wrappers own the self-distance fix-up
        D = pairwise_l2_batched(features, squared=False, use_kernel=uk,
                                zero_diag=True)
        res = kmedoids_batched(D, valid, budget, max_sweeps=max_sweeps,
                               use_kernel=uk)
    return Coreset(indices=res.medoids,
                   weights=res.weights.astype(jnp.float32),
                   objective=res.objective,
                   assignment=res.assignment)


def coreset_epsilon(grads_full: jnp.ndarray, coreset: Coreset) -> jnp.ndarray:
    """Audit Assumption A.3 on *true* per-sample gradients.

    grads_full: (m, P) matrix of per-sample gradients (flattened).
    Returns ε = (1/m)‖Σⱼ gⱼ − Σₖ δₖ g_{medoid k}‖₂.
    """
    m = grads_full.shape[0]
    full = jnp.sum(grads_full, axis=0)
    sel = grads_full[coreset.indices]
    approx = jnp.sum(sel * coreset.weights[:, None], axis=0)
    return jnp.linalg.norm(full - approx) / m


def coreset_batch(data: dict, coreset: Coreset, m_full: int) -> dict:
    """Materialize the weighted coreset training set from a client dataset.

    Weights are δₖ·(k not dropped)/mⁱ-normalized implicitly by the weighted
    loss (which divides by Σw), matching Eq.(9)'s (1/mⁱ)Σδₖ∇Lₖ since
    Σₖ δₖ = mⁱ.
    """
    idx = np.asarray(coreset.indices)
    out = {k: v[idx] for k, v in data.items() if k != "weights"}
    out["weights"] = jnp.asarray(coreset.weights, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# configuration record for the FL runtime
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedCoreConfig:
    epochs: int = 10             # E
    deadline: Optional[float] = None  # τ (seconds); None = no deadline
    backend: str = "jax"         # kmedoids solver
    # tri-state Pallas switch: None = auto (kernels on supported backends,
    # jnp fallback otherwise); True/False force on/off
    use_kernel: Optional[bool] = None
    max_sweeps: int = 50
    refresh_every_round: bool = True  # paper: re-select each round
    projection_dim: Optional[int] = None  # JL projection (§Perf H3)
    # Alg. 1 drop path for clients that cannot meet τ even with the §4.4
    # minimal plan (coreset of 1, one partial epoch).  Default False:
    # train the minimal plan and mark ClientResult.deadline_violated.
    drop_infeasible: bool = False
