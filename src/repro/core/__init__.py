"""FedCore's primary contribution: distributed coreset selection.

Coreset problem (Eq.2) -> k-medoids reformulation (Eq.5) -> gradient-proxy
features (§4.3), plus the ε-approximation audit for Assumption A.3.
"""
from repro.core.coreset import (  # noqa: F401
    Coreset,
    FedCoreConfig,
    build_coreset,
    coreset_batch,
    coreset_budget,
    coreset_epsilon,
    needs_coreset,
)
from repro.core.gradients import grad_features, true_per_sample_grads  # noqa: F401
from repro.core.kmedoids import (  # noqa: F401
    KMedoidsResult,
    kmedoids_jax,
    kmedoids_numpy,
    pairwise_sq_dists,
)
