"""Gradient-feature extraction for FedCore (§4.3).

``grad_features(model, params, data)`` returns the (m, F) matrix the
k-medoids clustering runs on:

  * ``feature_space == "input"``           — convex models: the raw inputs
    (d̃ⱼₖ = ‖xⱼ − xₖ‖; static across rounds, Allen-Zhu-style bound).
  * ``feature_space == "last_layer_grad"`` — DNNs: ∂L/∂z at the last layer
    input, computed **in closed form** from the softmax residual pulled back
    through the output matrix — one forward pass, no per-sample backprop
    (the paper's "attainable from the first epoch ... no extra computation").

``true_per_sample_grads`` computes exact per-sample full-model gradients with
vmap-of-grad — O(m) backprops, used only by tests and the ε-audit benchmark
to certify the proxy (never in the training path).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def grad_features(model, params, data: dict, batch_size: int = 512
                  ) -> jnp.ndarray:
    """Per-sample gradient features for a whole client dataset."""
    space = getattr(model, "feature_space", "last_layer_grad")
    if space == "input":
        x = data["x"]
        return x.reshape(x.shape[0], -1)
    m = _num_examples(data)
    feats = []
    for lo in range(0, m, batch_size):
        batch = {k: v[lo:lo + batch_size] for k, v in data.items()}
        feats.append(model.grad_features(params, batch))
    return jnp.concatenate(feats, axis=0)


def true_per_sample_grads(loss_fn: Callable, params, data: dict,
                          batch_size: int = 64) -> np.ndarray:
    """Exact per-sample gradients, flattened to (m, P).  Test/audit only."""

    def single(p, example):
        batch = {k: v[None] for k, v in example.items()}
        loss, _ = loss_fn(p, batch)
        return loss

    grad_one = jax.grad(single)
    vgrad = jax.jit(jax.vmap(grad_one, in_axes=(None, 0)))
    m = _num_examples(data)
    outs = []
    for lo in range(0, m, batch_size):
        batch = {k: v[lo:lo + batch_size] for k, v in data.items()}
        g = vgrad(params, batch)
        flat = jnp.concatenate(
            [x.reshape(x.shape[0], -1) for x in jax.tree.leaves(g)], axis=1)
        outs.append(np.asarray(flat))
    return np.concatenate(outs, axis=0)


def _num_examples(data: dict) -> int:
    return next(iter(data.values())).shape[0]


def project_features(feats: jnp.ndarray, dim: int, seed: int = 0
                     ) -> jnp.ndarray:
    """Johnson-Lindenstrauss random projection of gradient features.

    Beyond-paper optimization (EXPERIMENTS.md §Perf H3): the k-medoids
    distance matrix costs O(m²·F); projecting the (m, F) features to
    F' = dim with a scaled Gaussian matrix preserves pairwise distances to
    (1±ε) w.h.p. while cutting the distance-matrix FLOPs by F/F'.
    No-op if dim >= F.
    """
    m, f = feats.shape
    if dim >= f:
        return feats
    key = jax.random.PRNGKey(seed)
    proj = jax.random.normal(key, (f, dim), feats.dtype) / jnp.sqrt(dim)
    return feats @ proj
