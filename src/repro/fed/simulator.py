"""System-heterogeneity simulator (paper §6.1 "Implementations").

Each client i gets a compute capability cⁱ ~ N(1, 0.25) (samples/sec,
clipped positive); training one sample for one epoch costs 1/cⁱ seconds, so
a full round costs E·mⁱ/cⁱ.  The per-round deadline τ is chosen so that the
slowest s% of clients cannot complete full-set training in time — those are
the stragglers.

For the asynchronous runtime the static cⁱ is additionally perturbed by a
``CapabilityTrace``: per-dispatch slowdown *episodes* (a two-state Markov
chain per client — devices go hot/contended for a few dispatches at a time)
plus i.i.d. lognormal jitter on each realized duration, so arrival
processes are realistic rather than deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.fed.cost import resolve_cost


@dataclasses.dataclass(frozen=True)
class ClientSpec:
    cid: int
    m: int          # training-set size
    c: float        # capability (cost units / second; legacy: samples/s)

    def full_round_time(self, epochs: int, cost=None) -> float:
        """E full-set epochs.  ``cost`` (a ``repro.fed.cost``
        ``WorkloadCostModel`` or per-sample scalar; None = legacy
        samples-cost-1.0) prices each sample-visit, so the same cⁱ
        yields workload-honest durations."""
        if cost is None:
            return epochs * self.m / self.c
        return resolve_cost(cost).full_round_time(self.m, self.c, epochs)


def sample_capabilities(n_clients: int, rng: np.random.Generator,
                        mean: float = 1.0, var: float = 0.25,
                        floor: float = 0.05) -> np.ndarray:
    c = rng.normal(mean, np.sqrt(var), n_clients)
    return np.maximum(c, floor)


def make_client_specs(sizes: Sequence[int], rng: np.random.Generator
                      ) -> List[ClientSpec]:
    caps = sample_capabilities(len(sizes), rng)
    return [ClientSpec(cid=i, m=int(m), c=float(c))
            for i, (m, c) in enumerate(zip(sizes, caps))]


def straggler_deadline(specs: Sequence[ClientSpec], epochs: int,
                       straggler_pct: float, cost=None) -> float:
    """τ such that the slowest `straggler_pct`% of clients exceed it."""
    times = np.array([s.full_round_time(epochs, cost) for s in specs])
    return float(np.percentile(times, 100.0 - straggler_pct))


def straggler_mask(specs: Sequence[ClientSpec], epochs: int, deadline: float,
                   cost=None) -> np.ndarray:
    return np.array([s.full_round_time(epochs, cost) > deadline
                     for s in specs])


# ---------------------------------------------------------------------------
# time-varying capability traces (async runtime)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceConfig:
    jitter_std: float = 0.15        # lognormal σ of per-dispatch duration jitter
    slowdown_prob: float = 0.05     # P(enter a slowdown episode) per dispatch
    slowdown_factor: float = 3.0    # capability divisor while in an episode
    slowdown_mean_len: float = 3.0  # mean episode length, in dispatches
    seed: int = 0


class CapabilityTrace:
    """Deterministic per-(client, dispatch) capability perturbations.

    Episode state follows a two-state Markov chain over each client's
    dispatch sequence; jitter is i.i.d. lognormal.  Both are drawn from a
    per-client stream keyed by ``(seed, cid)`` and extended lazily in
    dispatch order, so the trace is a pure function of
    ``(seed, cid, dispatch_index)`` regardless of how the global event
    loop interleaves clients — a requirement for replayable event logs.
    """

    def __init__(self, cfg: TraceConfig | None = None):
        self.cfg = cfg or TraceConfig()
        self._entries: Dict[int, List[Tuple[bool, float]]] = {}
        self._rngs: Dict[int, np.random.Generator] = {}

    def _entry(self, cid: int, dispatch_index: int) -> Tuple[bool, float]:
        ent = self._entries.setdefault(cid, [])
        rng = self._rngs.get(cid)
        if rng is None:
            rng = np.random.default_rng(
                np.random.SeedSequence((self.cfg.seed, cid)))
            self._rngs[cid] = rng
        stay = 1.0 - 1.0 / max(self.cfg.slowdown_mean_len, 1.0)
        while len(ent) <= dispatch_index:
            in_episode = ent[-1][0] if ent else False
            p = stay if in_episode else self.cfg.slowdown_prob
            slowed = bool(rng.random() < p)
            # mean-1 multiplicative noise: E[lognormal(-σ²/2, σ)] = 1, so
            # jitter doesn't systematically inflate durations vs sync
            sig = self.cfg.jitter_std
            jitter = (float(rng.lognormal(-0.5 * sig * sig, sig))
                      if sig > 0 else 1.0)
            ent.append((slowed, jitter))
        return ent[dispatch_index]

    def capability(self, spec: ClientSpec, dispatch_index: int) -> float:
        """Effective cⁱ for this dispatch (known to the client at start,
        so deadline-aware strategies plan with it)."""
        slowed, _ = self._entry(spec.cid, dispatch_index)
        return spec.c / self.cfg.slowdown_factor if slowed else spec.c

    def jitter(self, spec: ClientSpec, dispatch_index: int) -> float:
        """Unpredictable multiplicative noise on the realized duration."""
        return self._entry(spec.cid, dispatch_index)[1]


class DispatchTraceIndexer:
    """Per-client dispatch cursors into a (possibly absent) trace.

    Every runtime that consumes a ``CapabilityTrace`` must index it by
    the client's *own* dispatch ordinal — NOT the round number — or
    clients that sit out rounds (adaptive cohorts, async scheduling)
    would skip trace entries and the run would stop being a pure
    function of ``(seed, cid, dispatch_index)``.  This helper owns those
    cursors; it replaces three hand-rolled ``dispatch_counts`` copies in
    ``fed/server.py``, ``fed/events.py``, and ``fed/fleet/batched.py``
    (the regression test in tests/test_obs.py pins the semantics).

    With ``trace=None`` the indexer still counts dispatches (telemetry
    wants the counts either way) and the perturbations are identities.
    """

    def __init__(self, n_clients: int, trace: CapabilityTrace | None):
        self.trace = trace
        self.counts = np.zeros(n_clients, dtype=np.int64)

    def begin(self, cid: int) -> int:
        """Allocate and return this dispatch's per-client ordinal."""
        k = int(self.counts[cid])
        self.counts[cid] += 1
        return k

    def capability(self, spec: ClientSpec, dispatch_index: int) -> float:
        if self.trace is None:
            return spec.c
        return self.trace.capability(spec, dispatch_index)

    def jitter(self, spec: ClientSpec, dispatch_index: int) -> float:
        if self.trace is None:
            return 1.0
        return self.trace.jitter(spec, dispatch_index)
