"""System-heterogeneity simulator (paper §6.1 "Implementations").

Each client i gets a compute capability cⁱ ~ N(1, 0.25) (samples/sec,
clipped positive); training one sample for one epoch costs 1/cⁱ seconds, so
a full round costs E·mⁱ/cⁱ.  The per-round deadline τ is chosen so that the
slowest s% of clients cannot complete full-set training in time — those are
the stragglers.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientSpec:
    cid: int
    m: int          # training-set size
    c: float        # capability (samples / second)

    def full_round_time(self, epochs: int) -> float:
        return epochs * self.m / self.c


def sample_capabilities(n_clients: int, rng: np.random.Generator,
                        mean: float = 1.0, var: float = 0.25,
                        floor: float = 0.05) -> np.ndarray:
    c = rng.normal(mean, np.sqrt(var), n_clients)
    return np.maximum(c, floor)


def make_client_specs(sizes: Sequence[int], rng: np.random.Generator
                      ) -> List[ClientSpec]:
    caps = sample_capabilities(len(sizes), rng)
    return [ClientSpec(cid=i, m=int(m), c=float(c))
            for i, (m, c) in enumerate(zip(sizes, caps))]


def straggler_deadline(specs: Sequence[ClientSpec], epochs: int,
                       straggler_pct: float) -> float:
    """τ such that the slowest `straggler_pct`% of clients exceed it."""
    times = np.array([s.full_round_time(epochs) for s in specs])
    return float(np.percentile(times, 100.0 - straggler_pct))


def straggler_mask(specs: Sequence[ClientSpec], epochs: int, deadline: float
                   ) -> np.ndarray:
    return np.array([s.full_round_time(epochs) > deadline for s in specs])
