from repro.fed.cost import (  # noqa: F401  (leaf module: import first)
    FORWARD_FRAC,
    UNIT_COST,
    CostPlan,
    WorkloadCostModel,
    resolve_cost,
    workload_cost_model,
)
from repro.fed.aggregators import (  # noqa: F401
    AGGREGATORS,
    ROBUST_METHODS,
    Aggregator,
    ClientUpdate,
    DelayedGradient,
    FedAsync,
    FedBuff,
    RobustAggregate,
    SyncWeightedMean,
    polynomial_staleness,
    robust_combine,
    stack_params,
    weighted_mean_params,
)
from repro.fed.events import (  # noqa: F401
    AsyncFLConfig,
    Event,
    EventQueue,
    run_federated_async,
)
from repro.fed.server import (  # noqa: F401
    FLConfig,
    RoundRecord,
    make_eval_fn,
    run_federated,
    sample_clients,
    summarize,
)
from repro.fed.simulator import (  # noqa: F401
    CapabilityTrace,
    ClientSpec,
    TraceConfig,
    make_client_specs,
    sample_capabilities,
    straggler_deadline,
    straggler_mask,
)
from repro.fed.strategies import (  # noqa: F401
    STRATEGIES,
    ClientResult,
    FedAvg,
    FedAvgDS,
    FedCore,
    FedProx,
    LocalTrainer,
    Strategy,
)

# fleet imports repro.fed.server/simulator, so this must stay the last
# import in this module (the submodules above are fully initialized by now)
from repro.fed.fleet import (  # noqa: E402,F401
    FAULT_PROFILES,
    SCENARIOS,
    AdaptiveParticipation,
    FaultProfile,
    FaultTrace,
    FleetConfig,
    FleetEngine,
    ParticipationConfig,
    build_scenario,
    dirichlet_label_skew,
    get_fault_profile,
    run_fleet,
    run_scenario,
)
