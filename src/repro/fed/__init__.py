from repro.fed.server import (  # noqa: F401
    FLConfig,
    RoundRecord,
    run_federated,
    sample_clients,
    summarize,
)
from repro.fed.simulator import (  # noqa: F401
    ClientSpec,
    make_client_specs,
    sample_capabilities,
    straggler_deadline,
    straggler_mask,
)
from repro.fed.strategies import (  # noqa: F401
    STRATEGIES,
    ClientResult,
    FedAvg,
    FedAvgDS,
    FedCore,
    FedProx,
    LocalTrainer,
    Strategy,
)
