"""Pluggable server-side aggregators for the sync and async FL runtimes.

Three families, all operating on whole client parameter trees:

  * ``SyncWeightedMean`` — the classic round-synchronous FedAvg rule
    w_{r+1} = Σᵢ αᵢ wᵢ / Σᵢ αᵢ with αᵢ = mⁱ (or 1), shared by
    ``run_federated`` and usable as a semi-sync buffered aggregator.
  * ``FedBuff`` — buffered asynchronous aggregation (Nguyen et al.,
    2022): updates accumulate in a size-K buffer; when full, the server
    mixes the staleness-discounted weighted mean of the buffer into the
    global model with server learning-rate η.
  * ``FedAsync`` — fully asynchronous staleness-polynomial mixing (Xie
    et al., 2019; cf. "Stragglers Are Not Disaster", arXiv 2102.06329):
    every arriving update is applied immediately as
    w ← (1 − α_t) w + α_t wᵢ with α_t = α·(1 + staleness)^{−a}.

Aggregators see one ``ClientUpdate`` at a time via ``apply`` and return
either new global params (the model version advances) or ``None`` (the
update was buffered).  Staleness is measured in server model versions:
how many aggregations were applied between the update's dispatch and its
arrival.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

from repro.utils.tree import tree_add, tree_scale, tree_sub, tree_weighted_mean

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """One client's contribution as seen by an aggregator."""
    params: Pytree
    n_samples: int
    staleness: int = 0          # server versions elapsed since dispatch
    base_params: Pytree = None  # global params the client trained from


def polynomial_staleness(staleness: int, exponent: float) -> float:
    """s(t) = (1 + t)^{−a} — the FedAsync polynomial discount."""
    return float((1.0 + staleness) ** -exponent)


def weighted_mean_params(trees: Sequence[Pytree], n_samples: Sequence[int],
                         weight_by_samples: bool = True) -> Pytree:
    """FedAvg aggregation: mean of ``trees`` weighted by mⁱ (or uniform)."""
    if weight_by_samples:
        weights = [float(n) for n in n_samples]
    else:
        weights = [1.0] * len(trees)
    return tree_weighted_mean(trees, weights)


class Aggregator:
    """Base: consume one update, maybe emit new global params."""
    name = "base"

    def apply(self, global_params: Pytree, update: ClientUpdate
              ) -> Optional[Pytree]:
        raise NotImplementedError

    def reset(self) -> None:
        """Drop buffered state; called by the engine at the start of a
        run so a reused aggregator cannot leak updates across runs."""

    def flush(self, global_params: Pytree) -> Optional[Pytree]:
        """Merge a partially-filled buffer at the end of a run.

        Buffering aggregators override this so tail updates — client
        work completed after the last full merge — are applied rather
        than silently dropped at ``max_virtual_time`` / queue
        exhaustion.  Returns new global params, or ``None`` when there
        is nothing buffered (the default for unbuffered rules)."""
        return None


class SyncWeightedMean(Aggregator):
    """Weighted mean over a fixed cohort of ``round_size`` updates.

    With ``round_size=None`` it is a pure helper for the synchronous
    server (call ``aggregate`` directly); with a round size it behaves
    as a semi-synchronous barrier inside the async engine.
    """
    name = "sync_mean"

    def __init__(self, weight_by_samples: bool = True,
                 round_size: Optional[int] = None):
        self.weight_by_samples = weight_by_samples
        self.round_size = round_size
        self._buffer: List[ClientUpdate] = []

    def aggregate(self, trees: Sequence[Pytree], n_samples: Sequence[int]
                  ) -> Pytree:
        return weighted_mean_params(trees, n_samples, self.weight_by_samples)

    def apply(self, global_params, update):
        if self.round_size is None:
            raise ValueError("SyncWeightedMean needs round_size to be used "
                             "as a streaming aggregator")
        self._buffer.append(update)
        if len(self._buffer) < self.round_size:
            return None
        buf, self._buffer = self._buffer, []
        return self.aggregate([u.params for u in buf],
                              [u.n_samples for u in buf])

    def flush(self, global_params):
        if not self._buffer:
            return None
        buf, self._buffer = self._buffer, []
        return self.aggregate([u.params for u in buf],
                              [u.n_samples for u in buf])

    def reset(self):
        self._buffer = []


class FedAsync(Aggregator):
    """Immediate staleness-polynomial mixing: one update ⇒ one version."""
    name = "fedasync"

    def __init__(self, mixing: float = 0.6, staleness_exponent: float = 0.5):
        if not 0.0 < mixing <= 1.0:
            raise ValueError(f"mixing must be in (0, 1], got {mixing}")
        self.mixing = mixing
        self.staleness_exponent = staleness_exponent

    def alpha(self, staleness: int) -> float:
        return self.mixing * polynomial_staleness(staleness,
                                                  self.staleness_exponent)

    def apply(self, global_params, update):
        a = self.alpha(update.staleness)
        return tree_weighted_mean([global_params, update.params],
                                  [1.0 - a, a])


class DelayedGradient(Aggregator):
    """Staleness-discounted delayed *deltas* (arXiv 2102.06329).

    Instead of mixing toward a stale client's absolute params (FedAsync),
    apply the progress the client actually made from its dispatch
    snapshot: w ← w + η·(1 + t)^{−a}·(wᵢ − w_dispatch).  Under heavy
    staleness and client heterogeneity this is far more stable, because a
    stale worker contributes its local improvement direction rather than
    dragging the global model back toward an old point.
    """
    name = "delayed_grad"

    def __init__(self, server_lr: float = 1.0,
                 staleness_exponent: float = 0.5):
        self.server_lr = server_lr
        self.staleness_exponent = staleness_exponent

    def apply(self, global_params, update):
        if update.base_params is None:
            raise ValueError("DelayedGradient needs ClientUpdate.base_params "
                             "(the dispatch-time global params)")
        scale = self.server_lr * polynomial_staleness(
            update.staleness, self.staleness_exponent)
        delta = tree_sub(update.params, update.base_params)
        return tree_add(global_params, tree_scale(delta, scale))


class FedBuff(Aggregator):
    """Buffered-K aggregation with per-update staleness discounting.

    Each buffered update carries weight (1+tᵢ)^{−a}, times mⁱ when
    ``weight_by_samples`` is set (off by default: the async engine
    already dispatches clients ∝ mⁱ, so weighting the buffer by mⁱ too
    would double-count size — same rationale as ``FLConfig``); when the
    buffer holds ``buffer_size`` updates the server applies
    w ← (1 − η) w + η · weighted_mean(buffer).  A partial buffer left at
    the end of a run is merged by ``flush`` (the runtimes call it on
    final drain and count it as a partial flush); a reused aggregator's
    ``reset()`` still discards anything a caller never flushed.
    """
    name = "fedbuff"

    def __init__(self, buffer_size: int = 10, staleness_exponent: float = 0.5,
                 server_lr: float = 1.0, weight_by_samples: bool = False):
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        if not 0.0 < server_lr <= 1.0:
            raise ValueError(f"server_lr must be in (0, 1], got {server_lr}")
        self.buffer_size = buffer_size
        self.staleness_exponent = staleness_exponent
        self.server_lr = server_lr
        self.weight_by_samples = weight_by_samples
        self._buffer: List[ClientUpdate] = []

    def _merge(self, buf: List[ClientUpdate], global_params: Pytree
               ) -> Pytree:
        weights = []
        for u in buf:
            w = float(u.n_samples) if self.weight_by_samples else 1.0
            weights.append(w * polynomial_staleness(u.staleness,
                                                    self.staleness_exponent))
        mean = tree_weighted_mean([u.params for u in buf], weights)
        if self.server_lr >= 1.0:
            return mean
        return tree_weighted_mean([global_params, mean],
                                  [1.0 - self.server_lr, self.server_lr])

    def apply(self, global_params, update):
        self._buffer.append(update)
        if len(self._buffer) < self.buffer_size:
            return None
        buf, self._buffer = self._buffer, []
        return self._merge(buf, global_params)

    def flush(self, global_params):
        if not self._buffer:
            return None
        buf, self._buffer = self._buffer, []
        return self._merge(buf, global_params)

    def reset(self):
        self._buffer = []


AGGREGATORS = {
    "sync_mean": SyncWeightedMean,
    "fedasync": FedAsync,
    "fedbuff": FedBuff,
    "delayed_grad": DelayedGradient,
}
