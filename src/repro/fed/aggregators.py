"""Pluggable server-side aggregators for the sync and async FL runtimes.

Three families, all operating on whole client parameter trees:

  * ``SyncWeightedMean`` — the classic round-synchronous FedAvg rule
    w_{r+1} = Σᵢ αᵢ wᵢ / Σᵢ αᵢ with αᵢ = mⁱ (or 1), shared by
    ``run_federated`` and usable as a semi-sync buffered aggregator.
  * ``FedBuff`` — buffered asynchronous aggregation (Nguyen et al.,
    2022): updates accumulate in a size-K buffer; when full, the server
    mixes the staleness-discounted weighted mean of the buffer into the
    global model with server learning-rate η.
  * ``FedAsync`` — fully asynchronous staleness-polynomial mixing (Xie
    et al., 2019; cf. "Stragglers Are Not Disaster", arXiv 2102.06329):
    every arriving update is applied immediately as
    w ← (1 − α_t) w + α_t wᵢ with α_t = α·(1 + staleness)^{−a}.

Aggregators see one ``ClientUpdate`` at a time via ``apply`` and return
either new global params (the model version advances) or ``None`` (the
update was buffered).  Staleness is measured in server model versions:
how many aggregations were applied between the update's dispatch and its
arrival.

A fourth family defends against the fault axes in
``repro.fed.fleet.faults``: the **robust combine rules** (coordinate-wise
trimmed mean and median, Krum / multi-Krum selection, and norm-clipping)
operate on a *stacked* update set — a pytree whose leaves carry a leading
client axis — so the fleet engines can feed them the vmapped per-client
parameter stacks they already produce.  ``robust_combine`` is the
functional entry point shared by all runtimes; ``RobustAggregate`` wraps
it as a buffered streaming aggregator for the event-driven async server.
Robust rules are deliberately *unweighted* over clients (trimmed mean /
median / Krum): sample-count weights are attacker-controlled metadata,
so honoring them would hand Byzantine clients a free amplifier.
``norm_clip`` keeps weights but bounds each client's delta norm first.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_add, tree_scale, tree_sub, tree_weighted_mean

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """One client's contribution as seen by an aggregator."""
    params: Pytree
    n_samples: int
    staleness: int = 0          # server versions elapsed since dispatch
    base_params: Pytree = None  # global params the client trained from


def polynomial_staleness(staleness: int, exponent: float) -> float:
    """s(t) = (1 + t)^{−a} — the FedAsync polynomial discount."""
    return float((1.0 + staleness) ** -exponent)


def weighted_mean_params(trees: Sequence[Pytree], n_samples: Sequence[int],
                         weight_by_samples: bool = True,
                         fallback: Pytree = None) -> Pytree:
    """FedAvg aggregation: mean of ``trees`` weighted by mⁱ (or uniform).

    With no contributing mass — an empty ``trees`` or all-zero weights —
    dividing by Σαᵢ would poison the model with NaNs; instead the round
    no-ops and returns ``fallback`` (the round-start params, matching the
    fleet engines' empty-cohort behaviour).  Without a fallback the
    degenerate case raises."""
    if weight_by_samples:
        weights = [float(n) for n in n_samples]
    else:
        weights = [1.0] * len(trees)
    if not trees or sum(weights) <= 0.0:
        if fallback is not None:
            return fallback
        raise ValueError("weighted_mean_params: no updates / all-zero "
                         "weights and no fallback params")
    return tree_weighted_mean(trees, weights)


class Aggregator:
    """Base: consume one update, maybe emit new global params."""
    name = "base"

    def apply(self, global_params: Pytree, update: ClientUpdate
              ) -> Optional[Pytree]:
        raise NotImplementedError

    def reset(self) -> None:
        """Drop buffered state; called by the engine at the start of a
        run so a reused aggregator cannot leak updates across runs."""

    def flush(self, global_params: Pytree) -> Optional[Pytree]:
        """Merge a partially-filled buffer at the end of a run.

        Buffering aggregators override this so tail updates — client
        work completed after the last full merge — are applied rather
        than silently dropped at ``max_virtual_time`` / queue
        exhaustion.  Returns new global params, or ``None`` when there
        is nothing buffered (the default for unbuffered rules)."""
        return None


class SyncWeightedMean(Aggregator):
    """Weighted mean over a fixed cohort of ``round_size`` updates.

    With ``round_size=None`` it is a pure helper for the synchronous
    server (call ``aggregate`` directly); with a round size it behaves
    as a semi-synchronous barrier inside the async engine.
    """
    name = "sync_mean"

    def __init__(self, weight_by_samples: bool = True,
                 round_size: Optional[int] = None):
        self.weight_by_samples = weight_by_samples
        self.round_size = round_size
        self._buffer: List[ClientUpdate] = []

    def aggregate(self, trees: Sequence[Pytree], n_samples: Sequence[int],
                  fallback: Pytree = None) -> Pytree:
        return weighted_mean_params(trees, n_samples, self.weight_by_samples,
                                    fallback=fallback)

    def apply(self, global_params, update):
        if self.round_size is None:
            raise ValueError("SyncWeightedMean needs round_size to be used "
                             "as a streaming aggregator")
        self._buffer.append(update)
        if len(self._buffer) < self.round_size:
            return None
        buf, self._buffer = self._buffer, []
        return self.aggregate([u.params for u in buf],
                              [u.n_samples for u in buf],
                              fallback=global_params)

    def flush(self, global_params):
        if not self._buffer:
            return None
        buf, self._buffer = self._buffer, []
        return self.aggregate([u.params for u in buf],
                              [u.n_samples for u in buf],
                              fallback=global_params)

    def reset(self):
        self._buffer = []


class FedAsync(Aggregator):
    """Immediate staleness-polynomial mixing: one update ⇒ one version."""
    name = "fedasync"

    def __init__(self, mixing: float = 0.6, staleness_exponent: float = 0.5):
        if not 0.0 < mixing <= 1.0:
            raise ValueError(f"mixing must be in (0, 1], got {mixing}")
        self.mixing = mixing
        self.staleness_exponent = staleness_exponent

    def alpha(self, staleness: int) -> float:
        return self.mixing * polynomial_staleness(staleness,
                                                  self.staleness_exponent)

    def apply(self, global_params, update):
        a = self.alpha(update.staleness)
        return tree_weighted_mean([global_params, update.params],
                                  [1.0 - a, a])


class DelayedGradient(Aggregator):
    """Staleness-discounted delayed *deltas* (arXiv 2102.06329).

    Instead of mixing toward a stale client's absolute params (FedAsync),
    apply the progress the client actually made from its dispatch
    snapshot: w ← w + η·(1 + t)^{−a}·(wᵢ − w_dispatch).  Under heavy
    staleness and client heterogeneity this is far more stable, because a
    stale worker contributes its local improvement direction rather than
    dragging the global model back toward an old point.
    """
    name = "delayed_grad"

    def __init__(self, server_lr: float = 1.0,
                 staleness_exponent: float = 0.5):
        self.server_lr = server_lr
        self.staleness_exponent = staleness_exponent

    def apply(self, global_params, update):
        if update.base_params is None:
            raise ValueError("DelayedGradient needs ClientUpdate.base_params "
                             "(the dispatch-time global params)")
        scale = self.server_lr * polynomial_staleness(
            update.staleness, self.staleness_exponent)
        delta = tree_sub(update.params, update.base_params)
        return tree_add(global_params, tree_scale(delta, scale))


class FedBuff(Aggregator):
    """Buffered-K aggregation with per-update staleness discounting.

    Each buffered update carries weight (1+tᵢ)^{−a}, times mⁱ when
    ``weight_by_samples`` is set (off by default: the async engine
    already dispatches clients ∝ mⁱ, so weighting the buffer by mⁱ too
    would double-count size — same rationale as ``FLConfig``); when the
    buffer holds ``buffer_size`` updates the server applies
    w ← (1 − η) w + η · weighted_mean(buffer).  A partial buffer left at
    the end of a run is merged by ``flush`` (the runtimes call it on
    final drain and count it as a partial flush); a reused aggregator's
    ``reset()`` still discards anything a caller never flushed.
    """
    name = "fedbuff"

    def __init__(self, buffer_size: int = 10, staleness_exponent: float = 0.5,
                 server_lr: float = 1.0, weight_by_samples: bool = False):
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        if not 0.0 < server_lr <= 1.0:
            raise ValueError(f"server_lr must be in (0, 1], got {server_lr}")
        self.buffer_size = buffer_size
        self.staleness_exponent = staleness_exponent
        self.server_lr = server_lr
        self.weight_by_samples = weight_by_samples
        self._buffer: List[ClientUpdate] = []

    def _merge(self, buf: List[ClientUpdate], global_params: Pytree
               ) -> Pytree:
        weights = []
        for u in buf:
            w = float(u.n_samples) if self.weight_by_samples else 1.0
            weights.append(w * polynomial_staleness(u.staleness,
                                                    self.staleness_exponent))
        if sum(weights) <= 0.0:
            return global_params
        mean = tree_weighted_mean([u.params for u in buf], weights)
        if self.server_lr >= 1.0:
            return mean
        return tree_weighted_mean([global_params, mean],
                                  [1.0 - self.server_lr, self.server_lr])

    def apply(self, global_params, update):
        self._buffer.append(update)
        if len(self._buffer) < self.buffer_size:
            return None
        buf, self._buffer = self._buffer, []
        return self._merge(buf, global_params)

    def flush(self, global_params):
        if not self._buffer:
            return None
        buf, self._buffer = self._buffer, []
        return self._merge(buf, global_params)

    def reset(self):
        self._buffer = []


# ---------------------------------------------------------------------------
# robust combine rules (Byzantine-resilient aggregation)
#
# All rules consume a *stacked* update set: a pytree whose every leaf has
# a leading client axis C — exactly the shape the vmapped fleet engines
# emit — and reduce the client axis with jnp ops, so they run as single
# fused XLA reductions rather than per-client Python loops.
# ---------------------------------------------------------------------------

ROBUST_METHODS = ("trimmed_mean", "median", "krum", "multi_krum", "norm_clip")


def stack_params(trees: Sequence[Pytree]) -> Pytree:
    """Stack per-client trees into one tree of (C, ...) leaves."""
    if not trees:
        raise ValueError("stack_params needs at least one tree")
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                        *trees)


def _flatten_stacked(stacked: Pytree) -> jnp.ndarray:
    """(C, D) float32 view of a stacked pytree, leaves concatenated."""
    leaves = jax.tree.leaves(stacked)
    return jnp.concatenate(
        [jnp.asarray(x).reshape(x.shape[0], -1).astype(jnp.float32)
         for x in leaves], axis=1)


def trimmed_mean_stacked(stacked: Pytree, trim_frac: float = 0.2) -> Pytree:
    """Coordinate-wise β-trimmed mean: sort each coordinate over the
    client axis, drop the ⌊βC⌋ smallest and largest values, average the
    rest.  Tolerates up to ⌊βC⌋ arbitrary clients per coordinate."""
    c = jax.tree.leaves(stacked)[0].shape[0]
    t = min(int(trim_frac * c), (c - 1) // 2)

    def red(x):
        if t == 0:
            return jnp.mean(x, axis=0)
        return jnp.mean(jnp.sort(x, axis=0)[t:c - t], axis=0)

    return jax.tree.map(red, stacked)


def median_stacked(stacked: Pytree) -> Pytree:
    """Coordinate-wise median over the client axis (the β → 1/2 limit of
    the trimmed mean; breakdown point just under C/2)."""
    return jax.tree.map(lambda x: jnp.median(x, axis=0), stacked)


def krum_select(stacked: Pytree, n_byzantine: Optional[int] = None,
                multi: int = 1) -> np.ndarray:
    """Krum / multi-Krum selection (Blanchard et al., 2017).

    Scores each client by the sum of its C − f − 2 smallest squared
    distances to the other updates and returns the ``multi``
    lowest-scoring client indices (ties broken by index — deterministic).
    ``n_byzantine`` defaults to ⌈C/4⌉."""
    v = _flatten_stacked(stacked)
    c = v.shape[0]
    f = int(n_byzantine) if n_byzantine is not None else max(1, c // 4)
    sq = jnp.sum(v * v, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (v @ v.T)
    d2 = jnp.maximum(d2, 0.0) + jnp.diag(jnp.full(c, jnp.inf))
    k_near = max(1, min(c - f - 2, c - 1))
    scores = np.asarray(
        jnp.sum(jnp.sort(d2, axis=1)[:, :k_near], axis=1), np.float64)
    order = np.argsort(scores, kind="stable")
    return order[:max(1, min(int(multi), c))]


def krum_stacked(stacked: Pytree, n_byzantine: Optional[int] = None,
                 multi: int = 1) -> Pytree:
    """Krum (``multi=1``: the single best-supported update) or
    multi-Krum (uniform mean of the ``multi`` selected updates)."""
    sel = krum_select(stacked, n_byzantine=n_byzantine, multi=multi)
    if len(sel) == 1:
        return jax.tree.map(lambda x: x[int(sel[0])], stacked)
    idx = jnp.asarray(np.sort(sel))
    return jax.tree.map(lambda x: jnp.mean(x[idx], axis=0), stacked)


def norm_clip_stacked(stacked: Pytree, base: Pytree,
                      weights: Optional[Sequence[float]] = None,
                      clip: Optional[float] = None) -> Pytree:
    """Norm-clipped weighted mean: each client's delta from ``base`` is
    scaled down to at most ``clip`` (default: the median delta norm, so
    the bound adapts to the honest majority), then the clipped deltas
    are weighted-averaged back onto ``base``.  Defangs scaled/boosted
    Byzantine updates while keeping sample-count weighting."""
    v = _flatten_stacked(stacked)
    vb = _flatten_stacked(jax.tree.map(lambda x: jnp.asarray(x)[None], base))[0]
    norms = jnp.linalg.norm(v - vb[None, :], axis=1)
    bound = jnp.median(norms) if clip is None else jnp.float32(clip)
    scale = jnp.minimum(1.0, bound / jnp.maximum(norms, 1e-12))
    c = v.shape[0]
    w = (jnp.ones(c, jnp.float32) if weights is None
         else jnp.asarray(np.asarray(weights, np.float32)))
    total = jnp.sum(w)
    coef = jnp.where(total > 0, w * scale / jnp.maximum(total, 1e-12), 0.0)
    out = jax.tree.map(
        lambda b, x: b + jnp.tensordot(coef, (x - b[None]).astype(jnp.float32),
                                       axes=1).astype(b.dtype),
        base, stacked)
    return jax.tree.map(
        lambda o, b: jnp.where(total > 0, o, b), out, base)


def robust_combine(stacked: Pytree, method: str,
                   weights: Optional[Sequence[float]] = None,
                   base: Pytree = None, trim_frac: float = 0.2,
                   n_byzantine: Optional[int] = None) -> Pytree:
    """Combine a (C, ...) stacked update set with a named rule.

    ``method`` is one of ``ROBUST_METHODS`` or ``"weighted_mean"`` (the
    non-robust baseline, included so runtimes dispatch through one entry
    point).  ``base`` — the round-start global params — is the fallback
    for an empty stack and the reference point for ``norm_clip``.
    Weights only affect ``weighted_mean`` and ``norm_clip``; the order-
    statistic rules are unweighted by design (see module docstring)."""
    c = (jax.tree.leaves(stacked)[0].shape[0]
         if jax.tree.leaves(stacked) else 0)
    if c == 0:
        if base is not None:
            return base
        raise ValueError("robust_combine: empty update stack and no base")
    if method == "weighted_mean":
        w = ([1.0] * c if weights is None else [float(x) for x in weights])
        if sum(w) <= 0.0:
            if base is not None:
                return base
            raise ValueError("robust_combine: all-zero weights and no base")
        wj = jnp.asarray(np.asarray(w, np.float32)) / np.float32(sum(w))
        return jax.tree.map(
            lambda x: jnp.tensordot(wj, jnp.asarray(x).astype(jnp.float32),
                                    axes=1), stacked)
    if method == "trimmed_mean":
        return trimmed_mean_stacked(stacked, trim_frac=trim_frac)
    if method == "median":
        return median_stacked(stacked)
    if method == "krum":
        return krum_stacked(stacked, n_byzantine=n_byzantine, multi=1)
    if method == "multi_krum":
        f = int(n_byzantine) if n_byzantine is not None else max(1, c // 4)
        return krum_stacked(stacked, n_byzantine=f,
                            multi=max(1, c - f - 2))
    if method == "norm_clip":
        if base is None:
            raise ValueError("norm_clip needs base (round-start) params")
        return norm_clip_stacked(stacked, base, weights=weights)
    raise ValueError(f"unknown combine method {method!r} (expected "
                     f"weighted_mean or one of {ROBUST_METHODS})")


class RobustAggregate(Aggregator):
    """Buffered robust aggregation for the streaming (async) server.

    Buffers ``round_size`` updates, then replaces the global model with
    ``robust_combine`` over the buffered stack — the semi-synchronous
    barrier shape of ``SyncWeightedMean``, with a Byzantine-resilient
    combine rule inside.  ``flush`` merges a partial tail buffer."""

    def __init__(self, method: str = "trimmed_mean", round_size: int = 8,
                 weight_by_samples: bool = True, trim_frac: float = 0.2,
                 n_byzantine: Optional[int] = None):
        if method not in ROBUST_METHODS:
            raise ValueError(f"unknown robust method {method!r} "
                             f"(expected one of {ROBUST_METHODS})")
        if round_size < 1:
            raise ValueError(f"round_size must be >= 1, got {round_size}")
        self.name = method
        self.method = method
        self.round_size = round_size
        self.weight_by_samples = weight_by_samples
        self.trim_frac = trim_frac
        self.n_byzantine = n_byzantine
        self._buffer: List[ClientUpdate] = []

    def _combine(self, buf: List[ClientUpdate], global_params: Pytree
                 ) -> Pytree:
        weights = ([float(u.n_samples) for u in buf]
                   if self.weight_by_samples else None)
        return robust_combine(stack_params([u.params for u in buf]),
                              self.method, weights=weights,
                              base=global_params, trim_frac=self.trim_frac,
                              n_byzantine=self.n_byzantine)

    def apply(self, global_params, update):
        self._buffer.append(update)
        if len(self._buffer) < self.round_size:
            return None
        buf, self._buffer = self._buffer, []
        return self._combine(buf, global_params)

    def flush(self, global_params):
        if not self._buffer:
            return None
        buf, self._buffer = self._buffer, []
        return self._combine(buf, global_params)

    def reset(self):
        self._buffer = []


AGGREGATORS = {
    "sync_mean": SyncWeightedMean,
    "fedasync": FedAsync,
    "fedbuff": FedBuff,
    "delayed_grad": DelayedGradient,
    **{m: functools.partial(RobustAggregate, m) for m in ROBUST_METHODS},
}
