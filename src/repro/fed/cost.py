"""Per-workload step cost: deadlines mean FLOPs, not samples (§4.2 honest).

FedCore's budget bⁱ = ⌊(cⁱτ − mⁱ)/(E−1)⌋ treats the deadline τ as a
*sample count* divided by a capability in samples/second — honest only
while every sample costs the same amount of compute.  The moment the
fleet runs a transformer next to an MLP that stops being true: a
capability cⁱ calibrated on one workload over- or under-commits on
another by exactly the ratio of their per-sample step costs.

This module makes the unit of work explicit.  A ``WorkloadCostModel``
carries the measured **cost per sample-visit** (one sample, one training
epoch) in abstract *cost units*; client capability cⁱ is cost units per
second.  Every budget/deadline formula in the repo routes through the
model:

  * ``available_samples(c, τ)`` — how many sample-visits fit in τ,
  * ``needs_coreset`` / ``budget`` — Alg. 1 line 6 and the §4.2 budget,
  * ``fallback_plan`` — the §4.4 forward-only plan with epoch shedding
    and footnote-2 honest-overrun accounting (previously copy-pasted
    between ``fed/strategies.py``, ``core/coreset.py`` callers, and
    ``fed/fleet/scheduler.py`` — this is now the one implementation),
  * ``duration`` / ``work_units`` — realized virtual-clock seconds and
    scheduler-EWMA work units from sample-visit counts.

**Legacy mode is byte-identical.**  The default ``UNIT_COST`` model
(cost_per_sample = 1.0) takes the exact arithmetic paths the formulas
used before this module existed — every branch below short-circuits the
×1.0 so goldens, BENCH gates, and event-log determinism are preserved
bit for bit.

Measurement reuses the ``launch/dryrun.py`` / ``benchmarks/roofline.py``
machinery: lower + compile the jitted local-SGD step and read
``compiled.cost_analysis()["flops"]``; when the backend reports no FLOPs
the fallback calibrates by wall-clock timing the compiled step.  Costs
are expressed *relative to a reference workload* (default ``"mlp"``) so
cost units stay commensurate with the simulator's cⁱ ~ N(1, 0.25)
capability draws.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

# forward-only pass cost relative to a full train step (fwd+bwd+update);
# the §4.4 fallback charges the feature pass at this fraction
FORWARD_FRAC = 1.0 / 3.0


@dataclasses.dataclass(frozen=True)
class CostPlan:
    """One client's training plan for a round under (m, c, τ, E)."""
    budget: int          # coreset size b (samples)
    eff_epochs: int      # epochs actually run (≤ E: extreme stragglers shed)
    work: float          # sample-visits charged (feature pass + epochs)
    violated: bool       # True: even this minimal plan overruns τ


@dataclasses.dataclass(frozen=True)
class WorkloadCostModel:
    """Cost units per sample-visit for one workload.

    ``cost_per_sample`` is the knob everything keys off: 1.0 is the
    legacy samples-are-the-unit mode; a measured model carries the
    workload's per-sample step cost relative to the reference workload.
    ``flops_per_sample`` preserves the raw HLO FLOPs when the model came
    from ``cost_analysis`` (None for legacy/wall-clock models).
    ``source`` ∈ {"legacy", "flops", "wallclock", "manual"}.
    """
    name: str = "unit"
    cost_per_sample: float = 1.0
    forward_frac: float = FORWARD_FRAC
    flops_per_sample: Optional[float] = None
    source: str = "legacy"

    @property
    def is_unit(self) -> bool:
        return self.cost_per_sample == 1.0

    # -- unit conversions --------------------------------------------------
    # Each conversion short-circuits ×1.0 / ÷1.0 so the unit model follows
    # the exact pre-refactor expressions (byte-identical legacy budgets).

    def available_samples(self, capability: float, deadline: float) -> float:
        """Sample-visits that fit in τ at capability c (cost units/s)."""
        avail = capability * deadline
        return avail if self.is_unit else avail / self.cost_per_sample

    def work_units(self, samples_visited) -> Any:
        """Cost units charged for visiting ``samples_visited`` samples."""
        if self.is_unit:
            return samples_visited
        return samples_visited * self.cost_per_sample

    def duration(self, samples_visited, capability) -> Any:
        """Virtual-clock seconds to visit ``samples_visited`` samples."""
        return self.work_units(samples_visited) / capability

    def full_round_time(self, m: int, capability: float, epochs: int
                        ) -> float:
        """E full-set epochs: the pre-coreset round time E·mⁱ·κ/cⁱ."""
        return self.duration(epochs * m, capability)

    # -- Alg. 1 budget arithmetic (the one implementation) -----------------

    def needs_coreset(self, m: int, capability: float, deadline: float,
                      epochs: int) -> bool:
        """Alg. 1 line 6: full-set training iff E·mⁱ sample-visits fit."""
        return epochs * m > self.available_samples(capability, deadline)

    def budget(self, m: int, capability: float, deadline: float,
               epochs: int) -> int:
        """bⁱ = ⌊(avail − mⁱ)/(E−1)⌋ clipped to [1, mⁱ] (paper §4.2)."""
        if epochs <= 1:
            return m
        avail = self.available_samples(capability, deadline)
        b = int(np.floor((avail - m) / (epochs - 1)))
        return max(1, min(b, m))

    def primary_plan(self, m: int, capability: float, deadline: float,
                     epochs: int) -> Optional[CostPlan]:
        """Alg. 1's primary schedule: full-set epoch 0 (which yields the
        gradient features) + E−1 coreset epochs at the §4.2 budget.
        Returns None when the budget floored at 1 still overruns τ — the
        caller falls back to ``fallback_plan``."""
        if epochs <= 1 or not self.available_samples(capability,
                                                     deadline) > m:
            return None
        b = self.budget(m, capability, deadline, epochs)
        work = m + (epochs - 1) * b
        if work > self.available_samples(capability, deadline):
            return None   # budget floored at 1 but still too slow
        return CostPlan(budget=b, eff_epochs=epochs, work=float(work),
                        violated=False)

    def fallback_plan(self, m: int, capability: float, deadline: float,
                      epochs: int) -> CostPlan:
        """§4.4 fallback: forward-only feature pass (``forward_frac`` of a
        train step per sample), coreset-only epochs, and epoch shedding
        for extreme stragglers.  ``violated`` implements footnote 2's
        honest accounting: when cⁱτ cannot even cover m/3 + b the client
        trains the minimal plan and the overrun is surfaced instead of
        silently clamping the reported time to τ."""
        avail = (self.available_samples(capability, deadline)
                 - self.forward_frac * m)
        budget = max(1, min(int(avail // epochs), m))
        eff_epochs = max(1, min(epochs, int(avail // budget)))
        work = self.forward_frac * m + eff_epochs * budget
        violated = bool(self.work_units(work)
                        > capability * deadline * (1.0 + 1e-9))
        return CostPlan(budget=budget, eff_epochs=eff_epochs, work=work,
                        violated=violated)


UNIT_COST = WorkloadCostModel()


def resolve_cost(cost: Any) -> WorkloadCostModel:
    """None → legacy unit model; a number → manual scalar model; a
    ``WorkloadCostModel`` passes through."""
    if cost is None:
        return UNIT_COST
    if isinstance(cost, WorkloadCostModel):
        return cost
    if isinstance(cost, (int, float, np.floating, np.integer)):
        return WorkloadCostModel(name=f"manual[{float(cost):g}]",
                                 cost_per_sample=float(cost),
                                 source="manual")
    raise TypeError(f"cannot resolve a cost model from {type(cost).__name__}")


# ---------------------------------------------------------------------------
# measurement: HLO FLOPs (primary) with wall-clock calibration fallback
# ---------------------------------------------------------------------------

def example_batch(workload, batch_size: int = 8) -> Dict[str, Any]:
    """A schema-shaped batch of zeros (+ unit loss weights) for lowering.

    FLOP counts depend on shapes, not values, so zeros are sufficient —
    including for int32 token fields (index 0 is a valid embedding row).
    """
    import jax.numpy as jnp
    batch = {name: jnp.zeros((batch_size,) + tuple(spec.shape),
                             dtype=spec.dtype)
             for name, spec in workload.schema.items()}
    batch["weights"] = jnp.ones((batch_size,), jnp.float32)
    return batch


def _compiled_flops(compiled) -> Optional[float]:
    """``compiled.cost_analysis()`` across jax versions (dict vs [dict])."""
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return None
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    if not cost:
        return None
    flops = float(cost.get("flops", -1.0))
    return flops if flops > 0 else None


def _lower_train_step(model, batch, lr: float = 0.05):
    """Lower + compile one jitted local-SGD step (fwd + bwd + update) —
    the same arithmetic shape every engine's inner loop runs."""
    import jax

    def step(params, b):
        def loss_fn(p):
            total, _ = model.loss(p, b)
            return total
        grads = jax.grad(loss_fn)(params)
        return jax.tree.map(lambda p, g: p - lr * g, params, grads)

    params = model.init(jax.random.PRNGKey(0))
    return jax.jit(step).lower(params, batch).compile(), params, batch


def measure_step_cost(model, batch, lr: float = 0.05,
                      timing_reps: int = 5) -> Tuple[float, str]:
    """(per-sample step cost, source) for one model on one example batch.

    Primary: HLO FLOPs from ``compiled.cost_analysis()`` (the
    ``launch/dryrun.py`` machinery).  Fallback: wall-clock calibration of
    the compiled step — min over ``timing_reps`` blocked executions.
    Either way the value scales per *sample*, so dividing two workloads'
    costs cancels the unit.
    """
    import jax
    compiled, params, batch = _lower_train_step(model, batch, lr)
    n = int(next(iter(jax.tree.leaves(batch))).shape[0])
    flops = _compiled_flops(compiled)
    if flops is not None:
        return flops / n, "flops"
    out = compiled(params, batch)           # warm up
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(1, timing_reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(params, batch))
        best = min(best, time.perf_counter() - t0)
    return best / n, "wallclock"


_MEASURED: Dict[Tuple[str, int], Tuple[float, str]] = {}


def _measured_workload_cost(workload, batch_size: int,
                            lr: float) -> Tuple[float, str]:
    key = (workload.name, batch_size)
    if key not in _MEASURED:
        _MEASURED[key] = measure_step_cost(
            workload, example_batch(workload, batch_size), lr=lr)
    return _MEASURED[key]


def workload_cost_model(workload, batch_size: int = 8, *,
                        relative_to: Any = "mlp",
                        lr: float = 0.05) -> WorkloadCostModel:
    """Measure a registered workload's cost model.

    ``workload`` is a ``FleetWorkload`` or registry name.  Costs are
    normalized by ``relative_to`` — a registry name (measured the same
    way; default ``"mlp"``, the original fleet workload whose samples the
    legacy capability unit implicitly priced at 1.0), a number, or None
    for raw per-sample units.  Measurements are cached per
    (workload, batch_size), so repeated calls never re-lower.
    """
    from repro.fed.fleet.workloads import get_workload
    if isinstance(workload, str):
        workload = get_workload(workload)
    value, source = _measured_workload_cost(workload, batch_size, lr)
    if isinstance(relative_to, str):
        ref = get_workload(relative_to)
        ref_value, ref_source = _measured_workload_cost(ref, batch_size, lr)
        if ref_source != source:
            # never mix FLOPs with seconds: re-measure both by wall clock
            value, source = measure_step_cost(
                workload, example_batch(workload, batch_size), lr=lr,
                timing_reps=5)
            ref_value, _ = measure_step_cost(
                ref, example_batch(ref, batch_size), lr=lr, timing_reps=5)
    elif relative_to is None:
        ref_value = 1.0
    else:
        ref_value = float(relative_to)
    return WorkloadCostModel(
        name=workload.name,
        cost_per_sample=value / ref_value,
        flops_per_sample=value if source == "flops" else None,
        source=source)
