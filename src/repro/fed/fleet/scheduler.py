"""Adaptive participation scheduling (FLANP-style) for fleet-scale FL.

Straggler-Resilient Federated Learning (Reisizadeh et al., 2020) observes
that early rounds are *statistically* cheap — a small cohort of fast
clients reaches the coarse-accuracy regime sooner — and that participation
should grow geometrically as the model's statistical accuracy begins to
demand more data.  This module implements that policy against the repo's
heterogeneity simulator:

  * **doubling cohorts**: start from the ``min_cohort`` fastest clients
    and grow the cohort by ``growth_factor`` whenever the train loss
    plateaus (no relative improvement ≥ ``plateau_tol`` for
    ``plateau_patience`` consecutive rounds);
  * **slowdown-aware selection**: client speed is ranked by an EWMA of
    *observed* capability (work units / realized duration, which folds in
    ``CapabilityTrace`` slowdown episodes and jitter), not the nominal
    cⁱ — a device in a contention episode drifts down the ranking and out
    of small cohorts.  A configurable ``explore_frac`` of each cohort is
    sampled uniformly from the remainder so observations never go fully
    stale;
  * **observed-capability coreset budgets**: ``budget(cid, τ, E)`` feeds
    the observed EWMA into the paper's bⁱ = ⌊(cⁱτ − mⁱ)/(E−1)⌋, so a
    client that has been running slow gets a smaller coreset than its
    spec sheet suggests — deadline compliance under *realized*, not
    nominal, capability.

The class is runtime-agnostic: ``repro.fed.server.run_federated``,
``repro.fed.events.run_federated_async`` and the batched fleet driver all
drive it through the same select/observe/record_round/budget protocol
(duck-typed to avoid an import cycle with ``repro.fed``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.fed.cost import resolve_cost
from repro.fed.simulator import ClientSpec
from repro.obs import get_recorder


@dataclasses.dataclass(frozen=True)
class ParticipationConfig:
    min_cohort: int = 8           # FLANP n₀
    max_cohort: Optional[int] = None   # cap (None = all clients)
    growth_factor: float = 2.0    # cohort multiplier on plateau
    plateau_tol: float = 0.02     # relative loss improvement that counts
    plateau_patience: int = 1     # plateaued rounds before growing
    ewma: float = 0.5             # observed-capability smoothing weight
    explore_frac: float = 0.125   # cohort fraction sampled outside the
    # fastest set, keeping slow-client estimates fresh
    seed: int = 0


class AdaptiveParticipation:
    """FLANP doubling cohorts + slowdown-aware sampling + adaptive budgets."""

    def __init__(self, specs: Sequence[ClientSpec],
                 cfg: ParticipationConfig | None = None, cost=None):
        self.cfg = cfg or ParticipationConfig()
        # per-sample step cost (repro.fed.cost; None = legacy unit): the
        # EWMA observes work in *cost units*, so ``budget`` divides τ by
        # what a sample-visit actually costs on this workload
        self.cost = resolve_cost(cost)
        self.specs = list(specs)
        self.n = len(self.specs)
        self.sizes = np.array([s.m for s in self.specs], np.int64)
        # prior for observed capability: the nominal spec value
        self.observed = np.array([s.c for s in self.specs], np.float64)
        self._n_obs = np.zeros(self.n, np.int64)
        self.cohort = min(self.cfg.min_cohort, self.n)
        self._best_loss = np.inf
        self._stall = 0
        self._round = 0
        self._rng = np.random.default_rng(self.cfg.seed)
        self.growth_log: List[int] = []   # rounds at which the cohort grew

    # -- participation ----------------------------------------------------

    def cohort_size(self) -> int:
        cap = self.cfg.max_cohort or self.n
        return int(min(self.cohort, cap, self.n))

    def _speed_order(self) -> np.ndarray:
        # stable sort: capability ties break by cid, keeping selection
        # deterministic for a given observation history
        return np.argsort(-self.observed, kind="stable")

    def select(self) -> np.ndarray:
        """This round's cohort: fastest-by-observation, plus exploration."""
        k = self.cohort_size()
        order = self._speed_order()
        n_explore = min(int(round(k * self.cfg.explore_frac)), self.n - k)
        fast = order[:k - n_explore]
        rest = order[k - n_explore:]
        if n_explore > 0 and len(rest):
            explore = self._rng.choice(rest, size=n_explore, replace=False)
            return np.sort(np.concatenate([fast, explore]))
        return np.sort(fast)

    def eligible_mask(self) -> np.ndarray:
        """Dispatch weights for the async runtime: 1.0 for the current
        fastest cohort, ``explore_frac`` for everyone else (0 disables
        exploration and the mask is strictly binary).  The soft tail is
        what keeps out-of-cohort capability estimates fresh under
        asynchrony — the same role ``explore_frac`` plays in
        ``select()``."""
        mask = np.full(self.n, self.cfg.explore_frac, np.float64)
        mask[self._speed_order()[: self.cohort_size()]] = 1.0
        return mask

    # -- feedback ---------------------------------------------------------

    def observe(self, cid: int, work_units: float, duration: float) -> None:
        """Fold one realized (work, duration) pair into the capability EWMA."""
        if duration <= 0 or work_units <= 0:
            return
        c_hat = work_units / duration
        a = self.cfg.ewma
        self.observed[cid] = (1.0 - a) * self.observed[cid] + a * c_hat
        self._n_obs[cid] += 1
        get_recorder().metrics.histogram(
            "scheduler.observed_capability").observe(c_hat)

    def record_round(self, train_loss: float) -> None:
        """FLANP growth test: grow the cohort when loss stops improving."""
        self._round += 1
        obs = get_recorder()
        if obs.enabled:     # the EWMA state, visible as gauges per round
            obs.metrics.gauge("scheduler.cohort_size").set(
                self.cohort_size())
            obs.metrics.gauge("scheduler.mean_observed_capability").set(
                float(self.observed.mean()))
            obs.metrics.gauge("scheduler.n_growths").set(
                len(self.growth_log))
        if not np.isfinite(train_loss):
            return
        if train_loss < self._best_loss * (1.0 - self.cfg.plateau_tol):
            self._best_loss = train_loss
            self._stall = 0
            return
        self._stall += 1
        if self._stall >= self.cfg.plateau_patience:
            if self.cohort_size() < (self.cfg.max_cohort or self.n):
                self.cohort = int(np.ceil(
                    self.cohort * self.cfg.growth_factor))
                self.growth_log.append(self._round)
            self._stall = 0

    # -- budgets ----------------------------------------------------------

    def budget(self, cid: int, deadline: float, epochs: int) -> int:
        """Coreset budget from *observed* capability (paper §4.2 with
        cⁱ ← EWMA of realized capability, in cost units/second)."""
        s = self.specs[cid]
        c_obs = float(self.observed[cid])
        if not self.cost.needs_coreset(s.m, c_obs, deadline, epochs):
            return s.m
        return self.cost.budget(s.m, c_obs, deadline, epochs)

    def summary(self) -> dict:
        return {
            "cohort": self.cohort_size(),
            "n_growths": len(self.growth_log),
            "mean_observed_capability": float(self.observed.mean()),
            "n_observed_clients": int((self._n_obs > 0).sum()),
        }

    # -- checkpoint/resume ------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot of all mutable state — observed-capability
        EWMA, plateau tracker, and the exploration RNG's bit-generator
        state — so a resumed run replays selection byte-identically."""
        return {
            "observed": self.observed.tolist(),
            "n_obs": self._n_obs.tolist(),
            "cohort": int(self.cohort),
            "best_loss": float(self._best_loss),
            "stall": int(self._stall),
            "round": int(self._round),
            "growth_log": list(self.growth_log),
            "rng_state": self._rng.bit_generator.state,
        }

    def load_state_dict(self, state: dict) -> None:
        self.observed = np.asarray(state["observed"], np.float64)
        self._n_obs = np.asarray(state["n_obs"], np.int64)
        self.cohort = int(state["cohort"])
        self._best_loss = float(state["best_loss"])
        self._stall = int(state["stall"])
        self._round = int(state["round"])
        self.growth_log = [int(r) for r in state["growth_log"]]
        self._rng.bit_generator.state = state["rng_state"]
