"""Batched fleet client engine: 1000+-client rounds without a per-client
Python loop.

The synchronous server (``repro.fed.server``) and the async event runtime
(``repro.fed.events``) both execute clients one at a time in Python — fine
for cohorts of 10, hopeless at fleet scale where a single round touches a
thousand devices.  This module executes an entire cohort as a handful of
XLA programs:

  * clients are padded into **cohort groups** keyed by (padded size M,
    quantized coreset budget k): every client in a group shares static
    shapes, so local SGD, gradient-feature extraction, and masked
    k-medoids all ``vmap`` over the client axis — selection is
    **distance-free** by default (the BUILD/Δ-sweep reductions consume
    the (C, M, F) feature stack via the feature-tiled Pallas kernels; no
    (C, M, M) distance tensor is ever materialized, so per-client M
    scales to the thousands), with ``FleetConfig.distance_free=False``
    keeping the materializing pairwise + D-input solver as the measured
    baseline;
  * per-client randomness (epoch permutations) is drawn host-side from
    ``(seed, round, cid)`` streams, so results are a pure function of the
    seed regardless of grouping or execution order;
  * the same arithmetic runs either vmapped (``engine="batched"``) or as
    the status-quo per-client Python loop (``engine="loop"``): one client
    at a time, one jitted dispatch per mini-batch step — the execution
    model of ``repro.fed.strategies.LocalTrainer`` that the batched
    engine replaces.  Both paths share every op, so they agree to
    numerical tolerance — `benchmarks/fleet_sweep.py` verifies the
    parity and measures the wall-clock gap, which is the whole point.

Local-training semantics (deliberately simpler than
``repro.fed.strategies`` so they batch): each epoch visits all M padded
slots in a seeded per-client permutation, B at a time; padded samples
carry zero loss weight, so a batch's gradient is the weighted mean over
its real samples only.  Straggling clients run Alg. 1: one full-set
epoch from the round-start params (which also yields the gradient
features), k-medoids coreset selection, then E−1 weighted full-batch
epochs on the coreset.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (load_server_meta, load_server_state,
                              save_server_state)
from repro.core.coreset import build_coreset_batched
from repro.fed.aggregators import ROBUST_METHODS, robust_combine
from repro.fed.cost import resolve_cost
from repro.fed.fleet.faults import (FaultTrace, corrupt_stacked,
                                    get_fault_profile)
from repro.fed.fleet.workloads import client_num_samples
from repro.fed.server import RoundRecord, make_eval_fn
from repro.fed.simulator import (CapabilityTrace, ClientSpec,
                                 DispatchTraceIndexer, TraceConfig,
                                 straggler_deadline)
from repro.obs import active_recorder, get_recorder

Pytree = Any


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    epochs: int = 2               # E
    batch_size: int = 32          # B
    lr: float = 0.05
    # tri-state Pallas switch for the selection fast path (distance stacks
    # + fused BUILD/Δ-sweep reductions): None = auto (kernels on supported
    # backends, jnp fallback otherwise); True/False force on/off
    use_kernel: Optional[bool] = None
    # distance-free selection (default on): the group program's k-medoids
    # reductions consume the (C, M, F) feature stack directly and the
    # (C, M, M) distance tensor is never materialized — O(C·M·F) peak
    # selection memory, per-client M in the thousands.  False keeps the
    # materializing pairwise + D-input solver as the A/B baseline
    # (benchmarks/fleet_sweep.py --selection-memory).
    distance_free: bool = True
    # adaptive cutover for distance_free: below this M the (C, M, M)
    # stack is cheap and streaming's recompute FLOPs cost more than the
    # memory saves, so selection materializes anyway (bit-identical to
    # the D-input path).  0 forces streaming at any size.
    materialize_below: int = 256
    max_sweeps: int = 25          # k-medoids swap sweeps
    weight_by_samples: bool = True  # aggregate ∝ mⁱ (fleet cohorts are not
    # sampled ∝ mⁱ, so size weighting is the unbiased choice here)
    seed: int = 0
    # per-sample step cost (repro.fed.cost.WorkloadCostModel; None =
    # legacy samples-cost-1.0): budgets, derived deadlines, and realized
    # durations all price a sample-visit through this model, so a
    # deadline means FLOPs, not raw sample counts.  Group quantization
    # (`_floor_pow4`) is unchanged — cost rescales what a budget *is*,
    # not how budgets map to cohort groups.
    cost: Any = None
    # server combine rule: "weighted_mean" (the FedAvg default) or one of
    # repro.fed.aggregators.ROBUST_METHODS (trimmed_mean / median / krum /
    # multi_krum / norm_clip) — the Byzantine-resilient rules fed by the
    # engines' per-client parameter stacks
    aggregator: str = "weighted_mean"


@dataclasses.dataclass
class CohortGroup:
    """A same-shape slice of a cohort: C clients padded to M samples.

    Arrays stay host-side (numpy): the batched engine moves each group to
    the device as one stack, while the loop reference converts one
    client's slice per dispatch — exactly the transfer pattern each
    execution model would have in production.

    ``data`` is a pytree of stacked (C, M, ...) arrays whose top level is
    a dict of named fields (the workload's schema — e.g. flat features,
    image tensors, or token sequences); everything below may be nested
    arbitrarily.  The engines only touch it through ``jax.tree`` ops, so
    no field name or rank is assumed anywhere."""
    cids: np.ndarray              # (C,) global client ids
    data: Pytree                  # stacked (C, M, ...) padded client data
    valid: np.ndarray             # (C, M) bool — real-sample mask
    m: np.ndarray                 # (C,) true sizes
    k: int                        # coreset budget (0 = full-set training)
    perms: np.ndarray             # (C, E, M) per-epoch sample permutations

    @property
    def n_clients(self) -> int:
        return len(self.cids)


@dataclasses.dataclass
class FleetRoundStats:
    """Per-client outcome of one fleet round, in cohort order.

    Dropped clients *stay in the stats* (their dispatch happened; only
    the update was lost), so trace accounting and scheduler observations
    remain aligned per-(client, dispatch) under fault injection — the
    ``dropped`` mask is what excluded them from aggregation."""
    cids: np.ndarray              # (N,)
    m: np.ndarray                 # (N,)
    budgets: np.ndarray           # (N,) effective budget (m if full-set)
    used_coreset: np.ndarray      # (N,) bool
    work: np.ndarray              # (N,) work units (samples visited)
    losses: np.ndarray            # (N,) final local train loss
    medoids: Dict[int, np.ndarray]  # cid -> (k,) selected sample indices
    dropped: np.ndarray = None    # (N,) bool — update lost mid-round
    corrupted: np.ndarray = None  # (N,) bool — Byzantine update merged


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _floor_pow4(n: int) -> int:
    """Largest power of 4 ≤ n — the coreset-budget quantizer.

    Rounding budgets *down* can never violate a deadline (any k ≤ bⁱ is
    deadline-safe); the coarse ×4 ladder keeps the number of distinct
    (M, k) cohort groups — and hence compiled programs and dispatches —
    small at fleet scale."""
    return 1 << (((max(int(n), 1).bit_length() - 1) // 2) * 2)


def _pad_rows(v: np.ndarray, m_pad: int) -> np.ndarray:
    """Pad axis 0 to ``m_pad`` by repeating the last row (finite values that
    keep feature scales sane; padded rows are masked everywhere)."""
    m = v.shape[0]
    if m == m_pad:
        return v
    return np.concatenate([v, np.repeat(v[-1:], m_pad - m, axis=0)])


def nominal_budgets(specs: Sequence[ClientSpec], deadline: float,
                    epochs: int, cost=None) -> Dict[int, int]:
    """Paper §4.2 budgets from nominal capabilities: bⁱ for clients that
    need a coreset under (τ, E), mⁱ (full set) for the rest.  The shared
    no-scheduler default of the fleet driver, sweep, and tests.  ``cost``
    (a ``repro.fed.cost.WorkloadCostModel`` or per-sample scalar; None =
    legacy) prices each sample-visit."""
    cm = resolve_cost(cost)
    return {s.cid: (cm.budget(s.m, s.c, deadline, epochs)
                    if cm.needs_coreset(s.m, s.c, deadline, epochs)
                    else s.m)
            for s in specs}


def _strip_weights(data: Pytree) -> Pytree:
    """Drop a caller-supplied top-level ``weights`` field (the engines
    derive loss weights from the padding mask)."""
    if isinstance(data, dict) and "weights" in data:
        return {kk: v for kk, v in data.items() if kk != "weights"}
    return data


def make_cohort_groups(clients_data: Sequence[Pytree],
                       cids: Sequence[int], budgets: Dict[int, int],
                       cfg: FleetConfig, round_seed: int = 0
                       ) -> List[CohortGroup]:
    """Bucket a cohort into same-shape groups.

    ``clients_data[cid]`` is any pytree of arrays sharing a leading sample
    axis (dict top level; see ``CohortGroup.data``) — the grouping logic
    is schema-generic.  ``budgets[cid]`` is the client's coreset budget;
    ``budgets[cid] >= m`` means full-set training.  Padded size M is the
    next power-of-two number of batches; coreset budgets are quantized
    down to a power of **four** (``_floor_pow4`` — the coarse ×4 ladder
    keeps the number of distinct compiled group programs small) so a
    group shares one static k (never exceeding any member's deadline
    budget).  Per-client epoch permutations are drawn from
    ``(cfg.seed, round_seed, cid)`` streams: the grouping is a pure
    performance choice and cannot change any client's arithmetic.
    """
    by_key: Dict[Tuple[int, int], List[int]] = {}
    for cid in cids:
        m = client_num_samples(clients_data[cid])
        m_pad = _next_pow2(-(-m // cfg.batch_size)) * cfg.batch_size
        b = int(budgets[cid])
        k = 0 if b >= m else _floor_pow4(b)
        by_key.setdefault((m_pad, k), []).append(cid)

    groups = []
    for (m_pad, k), members in sorted(by_key.items()):
        stacked = jax.tree.map(
            lambda *vs: np.stack([_pad_rows(np.asarray(v), m_pad)
                                  for v in vs]),
            *[_strip_weights(clients_data[cid]) for cid in members])
        ms = np.array([client_num_samples(clients_data[cid])
                       for cid in members])
        valid = np.arange(m_pad)[None, :] < ms[:, None]
        base = np.tile(np.arange(m_pad), (cfg.epochs, 1))
        perms = np.stack([
            np.random.default_rng(
                np.random.SeedSequence((cfg.seed, round_seed, cid))
            ).permuted(base, axis=1)
            for cid in members]).astype(np.int32)
        groups.append(CohortGroup(
            cids=np.array(members), data=stacked,
            valid=valid, m=ms, k=k, perms=perms))
    return groups


class FleetEngine:
    """Holds the jitted cohort programs (compiled once per group shape).

    ``run_group(..., batched=True)`` executes all C clients of a group as
    **one jitted per-group round program**: the straggler path
    (grad features → distance stack → fused-Δ-sweep k-medoids → epoch-0
    SGD → coreset gather → E−1 coreset epochs) compiles into a single
    XLA dispatch with ``donate_argnums`` on the broadcast parameter stack
    and the group data (the pre-fusion engine issued six dispatches per
    group with host round-trips between them).  ``batched=False`` is the
    status-quo per-client Python loop: the same mini-batch steps, feature
    pass, and masked k-medoids solve, but dispatched one client at a time
    with one jitted call per training step — the
    ``LocalTrainer.run_epochs`` execution model.  Identical arithmetic,
    so results match; only the dispatch structure differs.

    ``dispatch_count`` counts top-level jitted program invocations —
    exactly one per group on the fused path, one per jitted step on the
    loop path — through the single ``count_dispatch`` accounting point
    shared with ``ShardedFleetEngine``, so batched and sharded runs of
    the same cohort report identical counts (asserted in the workload
    conformance matrix).  The benchmark's dispatches-per-group breakdown
    and the single-dispatch regression test read it.
    """

    def __init__(self, model, cfg: FleetConfig):
        self.model = model
        self.cfg = cfg
        self.dispatch_count = 0

        def sgd_step(p, data, w, ix):
            """One mini-batch SGD step for one client."""
            batch = dict(jax.tree.map(lambda v: v[ix], data))
            batch["weights"] = w[ix]
            (loss, _), g = jax.value_and_grad(
                model.loss, has_aux=True)(p, batch)
            p = jax.tree.map(lambda a, b: a - cfg.lr * b, p, g)
            return p, loss

        def sgd_scan(params, data, w, idx):
            """One client: scan the step over idx (T, B) batches."""
            def step(p, ix):
                return sgd_step(p, data, w, ix)
            params, losses = jax.lax.scan(step, params, idx)
            return params, losses[-1]

        def core_step(p, cdata, cw):
            """One weighted full-batch epoch on one client's coreset."""
            batch = dict(cdata, weights=cw)
            (loss, _), g = jax.value_and_grad(
                model.loss, has_aux=True)(p, batch)
            p = jax.tree.map(lambda a, b: a - cfg.lr * b, p, g)
            return p, loss

        def core_scan(params, cdata, cw, n_steps_arr):
            """One client: E−1 weighted full-batch epochs on its coreset."""
            def step(p, _):
                return core_step(p, cdata, cw)
            params, losses = jax.lax.scan(step, params, n_steps_arr)
            return params, losses[-1]

        # raw per-client programs — the fused group bodies re-vmap these
        # (and the sharded engine wraps the same bodies in shard_map) so
        # all three execution modes share one copy of the arithmetic
        self._sgd_scan = sgd_scan
        self._core_scan = core_scan
        # fused per-group round programs, compiled per (k, data treedef) —
        # the treedef key is what lets schema-diverse workloads (images,
        # token sequences, nested field trees) share one engine instance
        self._group_programs: Dict[Tuple[int, Any], Any] = {}
        # fused selection-only programs (benchmark A/B + dispatch tests)
        self._select_programs: Dict[Tuple[int, Any], Any] = {}
        # standalone batched feature pass: first stage of the pre-fusion
        # dispatch chain, kept as the selection benchmark's baseline
        self._feats = jax.jit(jax.vmap(
            lambda p, d: model.grad_features(p, d), in_axes=(None, 0)))
        # per-client loop reference programs (one dispatch per step)
        self._sgd_step1 = jax.jit(sgd_step)
        self._core_step1 = jax.jit(core_step)
        self._feats1 = jax.jit(model.grad_features)

    # -- dispatch accounting + program-cache observability ----------------

    def count_dispatch(self, n: int = 1) -> None:
        """THE dispatch accounting point: every top-level jitted program
        invocation on any engine (batched, sharded, loop) goes through
        here, so counts are comparable across execution modes."""
        self.dispatch_count += n
        get_recorder().metrics.counter("fleet.dispatches").inc(n)

    def _cached_program(self, cache: Dict, key, build, kind: str):
        """Program-cache lookup with hit/miss counters per cache kind."""
        fn = cache.get(key)
        if fn is None:
            fn = build()
            cache[key] = fn
            get_recorder().metrics.counter(
                f"program_cache.{kind}.miss").inc()
        else:
            get_recorder().metrics.counter(f"program_cache.{kind}.hit").inc()
        return fn

    @contextmanager
    def _dispatch_span(self, name: str, program, **attrs):
        """Span around one top-level program invocation, stamping whether
        this call compiled (the jit cache grew) so first-call compile
        time is split from steady-state dispatch time in reports."""
        obs = get_recorder()
        if not obs.enabled:
            yield
            return
        size_fn = getattr(program, "_cache_size", None)
        before = size_fn() if callable(size_fn) else -1
        with obs.span(name, **attrs) as sp:
            yield
            if before >= 0:
                grew = size_fn() > before
                sp.attrs["compile"] = grew
                if grew:
                    obs.metrics.counter("program_cache.compiles").inc()
                    if before > 0:
                        obs.metrics.counter("program_cache.recompiles").inc()

    # -- fused group programs ---------------------------------------------

    def _make_group_body(self, k: int):
        """One cohort group's full round as a single traced body.

        ``k == 0``: E epochs of mini-batch SGD.  ``k > 0``: the Alg. 1
        straggler path — features at round-start params, fused coreset
        selection, one full-set epoch, E−1 weighted coreset epochs.
        Signature (k == 0): ``body(params, p0, data, w, idx)``;
        (k > 0): ``body(params, p0, data, w, valid, idx1, steps)``; both
        return ``(params (C, ...), losses (C,), medoid indices or
        None)``.  ``p0`` is the pre-broadcast (C, ...) parameter stack —
        passed in (rather than built inside) so the jitted wrapper can
        donate its buffers to the same-shaped output stack.  The sharded
        engine wraps this exact body in ``shard_map`` (per-device client
        lanes + psum aggregation), which is what keeps the loop / batched
        / sharded parity contract a single copy of the arithmetic.
        """
        cfg = self.cfg
        model = self.model
        vm_sgd = jax.vmap(self._sgd_scan)
        vm_core = jax.vmap(self._core_scan)
        vm_feats = jax.vmap(lambda p, d: model.grad_features(p, d),
                            in_axes=(None, 0))
        vm_gather = jax.vmap(lambda v, ix: v[ix])

        if k == 0:
            def body(params, p0, data, w, idx):
                p, losses = vm_sgd(p0, data, w, idx)
                return p, losses, None
            return body

        def body(params, p0, data, w, valid, idx1, steps):
            feats = vm_feats(params, data)                 # (C, M, F)
            coreset = build_coreset_batched(
                feats, valid, k, use_kernel=cfg.use_kernel,
                max_sweeps=cfg.max_sweeps,
                distance_free=cfg.distance_free,
                materialize_below=cfg.materialize_below)
            p, _ = vm_sgd(p0, data, w, idx1)
            cdata = jax.tree.map(
                lambda v: vm_gather(v, coreset.indices), data)  # (C, k, ...)
            p, losses = vm_core(p, cdata, coreset.weights, steps)
            return p, losses, coreset.indices
        return body

    @staticmethod
    def _donate_argnums() -> Tuple[int, ...]:
        """Donate (p0, data): the broadcast parameter stack is reused for
        the same-shaped output stack and the group data dies with the
        program.  CPU has no donation support (it would only warn), so
        only accelerators opt in."""
        return (1, 2) if jax.default_backend() != "cpu" else ()

    def _group_program(self, k: int, data_treedef):
        def build():
            return jax.jit(self._make_group_body(k),
                           donate_argnums=self._donate_argnums())
        return self._cached_program(self._group_programs, (k, data_treedef),
                                    build, "group")

    def _selection_program(self, k: int, data_treedef):
        """Selection phase only (features → distances → k-medoids) as one
        jitted dispatch — the benchmark's fused measurement unit."""
        def build():
            cfg = self.cfg
            vm_feats = jax.vmap(
                lambda p, d: self.model.grad_features(p, d),
                in_axes=(None, 0))

            def select(params, data, valid):
                feats = vm_feats(params, data)
                return build_coreset_batched(
                    feats, valid, k, use_kernel=cfg.use_kernel,
                    max_sweeps=cfg.max_sweeps,
                    distance_free=cfg.distance_free,
                    materialize_below=cfg.materialize_below)
            return jax.jit(select)
        return self._cached_program(self._select_programs, (k, data_treedef),
                                    build, "select")

    def select_group_coresets(self, params: Pytree, group: CohortGroup,
                              fused: bool = True):
        """Run one straggler group's selection phase; returns
        (``Coreset`` of stacked fields, dispatches issued).

        ``fused=True`` is the fast path: one jitted program (distance-free
        by default — no (C, M, M) intermediate).  ``fused=False`` replays
        the pre-fusion dispatch chain — a jitted feature pass, a jitted
        pairwise program (diagonal fix-up folded in via ``zero_diag``;
        the eager ``D * (1 − eye)`` epilogue it replaced allocated a
        second (C, M, M) tensor), and a jitted legacy-sweep k-medoids
        solve, with the host walking results between them — as the
        selection benchmark's A/B baseline.
        """
        if group.k == 0:
            raise ValueError("group has no selection phase (k == 0)")
        cfg = self.cfg
        data = jax.tree.map(jnp.asarray, group.data)
        valid = jnp.asarray(group.valid)
        obs = get_recorder()
        if fused:
            program = self._selection_program(group.k,
                                              jax.tree.structure(data))
            self.count_dispatch()
            with self._dispatch_span("selection", program, k=group.k,
                                     n_clients=group.n_clients, fused=True):
                coreset = program(params, data, valid)
            return coreset, 1
        from repro.core.coreset import Coreset
        from repro.core.kmedoids import kmedoids_batched
        from repro.kernels.ops import pairwise_l2_batched
        with obs.span("grad_features", k=group.k):
            feats = self._feats(params, data)              # dispatch 1
        with obs.span("distances", k=group.k):
            # zero_diag folds the self-distance fix-up into the jitted
            # pairwise program — the eager `D * (1 - eye)` epilogue it
            # replaces allocated a second (C, M, M) tensor per group
            D = pairwise_l2_batched(feats, squared=False,  # dispatch 2
                                    use_kernel=False, zero_diag=True)
        with obs.span("selection", k=group.k, fused=False):
            res = kmedoids_batched(D, valid, group.k,      # dispatch 3
                                   max_sweeps=cfg.max_sweeps,
                                   use_kernel=False, legacy_sweep=True)
        self.count_dispatch(3)
        return Coreset(indices=res.medoids,
                       weights=res.weights.astype(jnp.float32),
                       objective=res.objective,
                       assignment=res.assignment), 3

    # -- helpers ----------------------------------------------------------

    def _broadcast_params(self, params: Pytree, c: int) -> Pytree:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (c,) + x.shape), params)

    def _batch_indices(self, group: CohortGroup, epochs: slice, sl: slice
                       ) -> jnp.ndarray:
        """(C, T, B) minibatch index tensor for the given epoch/client
        ranges (sliced host-side so the loop path pays per-client, not
        per-group, conversion cost)."""
        sel = group.perms[sl, epochs]                      # (C, e, M)
        c, e, m_pad = sel.shape
        b = self.cfg.batch_size
        return jnp.asarray(sel.reshape(c, e * (m_pad // b), b))

    # -- group execution --------------------------------------------------

    def _run_group_stacked(self, params: Pytree, group: CohortGroup,
                           sl: slice) -> Tuple[Pytree, jnp.ndarray,
                                               Optional[jnp.ndarray]]:
        """Run clients ``sl`` of a group as ONE jitted dispatch; returns
        (params (C,...), losses, medoid indices or None)."""
        cfg = self.cfg
        # asarray never changes the treedef, so the program cache can be
        # consulted before staging — letting the dispatch span charge the
        # host-side transfers to the phase they belong to
        program = self._group_program(group.k,
                                      jax.tree.structure(group.data))
        self.count_dispatch()
        name = "local_sgd" if group.k == 0 else "coreset_group"

        if group.k == 0:    # full-set: E epochs of minibatch SGD
            with self._dispatch_span(name, program, k=0,
                                     n_clients=group.n_clients):
                # host-side slice, then one device transfer per call: the
                # batched path ships the whole group at once, the loop
                # path one client at a time
                data = jax.tree.map(lambda v: jnp.asarray(v[sl]),
                                    group.data)
                c = int(jax.tree.leaves(data)[0].shape[0])
                w = jnp.asarray(group.valid[sl].astype(np.float32))
                p0 = self._broadcast_params(params, c)
                idx = self._batch_indices(group, slice(None), sl)
                p, losses, _ = program(params, p0, data, w, idx)
            return p, losses, None

        # Alg. 1 straggler path: features at round-start params, fused
        # coreset selection, one full-set epoch, E−1 coreset epochs —
        # all inside the one program.
        with self._dispatch_span(name, program, k=group.k,
                                 n_clients=group.n_clients):
            data = jax.tree.map(lambda v: jnp.asarray(v[sl]), group.data)
            c = int(jax.tree.leaves(data)[0].shape[0])
            w = jnp.asarray(group.valid[sl].astype(np.float32))  # (C, M)
            p0 = self._broadcast_params(params, c)
            idx1 = self._batch_indices(group, slice(0, 1), sl)
            valid = jnp.asarray(group.valid[sl])
            steps = jnp.zeros((c, max(cfg.epochs - 1, 1)))
            p, losses, meds = program(params, p0, data, w, valid, idx1,
                                      steps)
        return p, losses, meds

    def _run_client_loop(self, params: Pytree, group: CohortGroup, c: int
                         ) -> Tuple[Pytree, float, Optional[np.ndarray]]:
        """Status-quo execution of one client: per-batch jitted dispatches
        (the ``LocalTrainer.run_epochs`` model), identical arithmetic to
        the vmapped lane."""
        cfg = self.cfg
        data = jax.tree.map(lambda v: jnp.asarray(v[c]), group.data)
        w = jnp.asarray(group.valid[c].astype(np.float32))
        m_pad = group.valid.shape[1]
        idx = group.perms[c].reshape(cfg.epochs,
                                     m_pad // cfg.batch_size,
                                     cfg.batch_size)

        def run_epoch(p, e):
            loss = 0.0
            for t in range(idx.shape[1]):
                p, loss = self._sgd_step1(p, data, w, jnp.asarray(idx[e, t]))
            self.count_dispatch(idx.shape[1])   # one jitted call per step
            return p, loss

        if group.k == 0:
            p, loss = params, 0.0
            for e in range(cfg.epochs):
                p, loss = run_epoch(p, e)
            return p, float(loss), None

        feats = self._feats1(params, data)
        self.count_dispatch()
        coreset = build_coreset_batched(
            feats[None], jnp.asarray(group.valid[c:c + 1]), group.k,
            use_kernel=cfg.use_kernel, max_sweeps=cfg.max_sweeps,
            distance_free=cfg.distance_free,
            materialize_below=cfg.materialize_below)
        p, _ = run_epoch(params, 0)
        med = np.asarray(coreset.indices[0])
        mix = jnp.asarray(med)
        cdata = jax.tree.map(lambda v: v[mix], data)
        cw = coreset.weights[0]
        loss = 0.0
        for _ in range(max(cfg.epochs - 1, 1)):
            p, loss = self._core_step1(p, cdata, cw)
        self.count_dispatch(max(cfg.epochs - 1, 1))
        return p, float(loss), med

    def run_group(self, params: Pytree, group: CohortGroup,
                  batched: bool = True) -> Tuple[Pytree, np.ndarray,
                                                 Optional[np.ndarray]]:
        if batched:
            p, losses, meds = self._run_group_stacked(
                params, group, slice(None))
            return (p, np.asarray(losses),
                    None if meds is None else np.asarray(meds))
        # the per-client Python loop the batched engine replaces
        ps, losses, meds = [], [], []
        name = "local_sgd" if group.k == 0 else "coreset_group"
        with get_recorder().span(name, k=group.k,
                                 n_clients=group.n_clients, mode="loop"):
            for c in range(group.n_clients):
                p, loss, med = self._run_client_loop(params, group, c)
                ps.append(p)
                losses.append(loss)
                if med is not None:
                    meds.append(med)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps)
        return (stacked, np.array(losses),
                np.stack(meds) if meds else None)


def weighted_param_sum(stacked: Pytree, weights) -> Pytree:
    """Σ_c w_c · p_c over a (C, ...) parameter stack — the host-side
    analogue of the sharded engine's ``weighted_psum_sum`` (one
    tensordot per leaf, no per-client loop).  The sync round mean and
    the async fleet engine's merge rules are both linear combinations of
    client stacks, so this is the one reduction they share."""
    ws = jnp.asarray(weights, jnp.float32)
    return jax.tree.map(
        lambda x: jnp.tensordot(ws, x.astype(jnp.float32), axes=(0, 0)),
        stacked)


def _aggregate_groups(partials: List[Tuple[Pytree, np.ndarray]],
                      fallback: Pytree) -> Pytree:
    """Weighted mean over all cohort clients: Σ_g Σ_c w·p / Σ w.

    ``partials`` holds per-group (stacked client params, per-client
    weights).  Group-partial sums keep the reduction order independent of
    engine choice (batched and loop produce identical stacks).  An empty
    cohort — or one whose aggregation weights sum to zero — contributes
    nothing: the round is a no-op and ``fallback`` (the round-start
    params) is returned unchanged.
    """
    total = sum(float(w.sum()) for _, w in partials)
    if not partials or total <= 0.0:
        return fallback
    acc = None
    for stacked, w in partials:
        part = weighted_param_sum(stacked, w)
        acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
    return jax.tree.map(lambda x: x / total, acc)


def _cat(parts: List[np.ndarray], dtype) -> np.ndarray:
    return (np.concatenate(parts).astype(dtype) if parts
            else np.zeros(0, dtype))


def run_fleet_round(engine: FleetEngine, params: Pytree,
                    clients_data: Sequence[Pytree],
                    cids: Sequence[int], budgets: Dict[int, int],
                    round_seed: int = 0, batched: bool = True,
                    groups: Optional[List[CohortGroup]] = None,
                    mode: Optional[str] = None,
                    aggregator: str = "weighted_mean",
                    faults: Optional[FaultTrace] = None,
                    dispatch_ordinals: Optional[Dict[int, int]] = None
                    ) -> Tuple[Pytree, FleetRoundStats]:
    """Execute one cohort round; returns (aggregated params, stats).

    ``mode`` selects the execution model: ``"batched"`` (vmapped cohort
    programs), ``"loop"`` (per-client reference), or ``"sharded"``
    (``engine`` must be a ``repro.fed.fleet.sharded.ShardedFleetEngine``;
    groups run data-parallel over the mesh's client axis with a psum-tree
    aggregation).  ``mode=None`` derives batched/loop from the legacy
    ``batched`` flag.  An empty cohort yields the round-start params and
    zero-length stats.  ``groups`` lets callers reuse a prebuilt cohort
    grouping (it is a pure function of (clients_data, cids, budgets, cfg,
    round_seed)).

    ``aggregator`` selects the server combine rule ("weighted_mean" or a
    robust method — the robust rules consume the engines' per-client
    parameter stacks).  ``faults`` injects mid-round dropout (the update
    is computed, then its aggregation weight is zeroed / its lane is
    excluded) and Byzantine corruption of the stack lanes;
    ``dispatch_ordinals`` maps cid → that client's dispatch ordinal for
    the per-(client, dispatch) fault draws (defaults to 0 — drivers pass
    the ``DispatchTraceIndexer`` cursors)."""
    cfg = engine.cfg
    obs = get_recorder()
    if mode is None:
        mode = "batched" if batched else "loop"
    if mode not in ("batched", "loop", "sharded"):
        raise ValueError(f"unknown fleet execution mode {mode!r}")
    if aggregator != "weighted_mean" and aggregator not in ROBUST_METHODS:
        raise ValueError(f"unknown fleet aggregator {aggregator!r} "
                         f"(expected weighted_mean or one of "
                         f"{ROBUST_METHODS})")
    if groups is None:
        with obs.span("cohort_build", n_clients=len(cids)):
            groups = make_cohort_groups(clients_data, cids, budgets, cfg,
                                        round_seed)
    has_dropout = faults is not None and faults.profile.has_dropout
    has_corruption = faults is not None and faults.profile.has_corruption
    # the weighted-mean-of-honest-lanes path never materializes stacks
    # (sharded keeps its psum); robust rules and corruption need them
    needs_stack = aggregator != "weighted_mean" or has_corruption
    ordinals = dispatch_ordinals or {}
    partials = []
    stacks: List[Tuple[Pytree, np.ndarray, np.ndarray]] = []
    all_cids, all_m, all_b, all_core, all_work, all_loss, all_meds = \
        [], [], [], [], [], [], []
    all_drop, all_corrupt = [], []
    medoids: Dict[int, np.ndarray] = {}
    for g in groups:
        w = (g.m.astype(np.float64) if cfg.weight_by_samples
             else np.ones(g.n_clients))
        ords = np.array([ordinals.get(int(c), 0) for c in g.cids], np.int64)
        drop = (np.array([faults.dropped(int(c), int(o))
                          for c, o in zip(g.cids, ords)], bool)
                if has_dropout else np.zeros(g.n_clients, bool))
        w_eff = np.where(drop, 0.0, w)
        if mode == "sharded":
            part, wsum, losses, meds, stack = engine.run_group_sharded(
                params, g, w_eff)
            if not needs_stack:
                partials.append((part, wsum))
        else:
            stack, losses, meds = engine.run_group(
                params, g, batched=(mode == "batched"))
            if not needs_stack:
                partials.append((stack, w_eff))
        corrupt = np.zeros(g.n_clients, bool)
        if needs_stack:
            if has_corruption:
                stack, _ = corrupt_stacked(stack, params, g.cids, ords,
                                           faults)
                corrupt = faults.byzantine[np.asarray(g.cids, np.int64)]
            stacks.append((stack, w_eff, drop))
        all_cids.append(g.cids)
        all_m.append(g.m)
        eff_b = g.m if g.k == 0 else np.full(g.n_clients, g.k)
        all_b.append(eff_b)
        all_core.append(np.full(g.n_clients, g.k > 0))
        work = (cfg.epochs * g.m if g.k == 0
                else g.m + (cfg.epochs - 1) * g.k * np.ones(g.n_clients,
                                                            np.int64))
        all_work.append(work)
        all_loss.append(losses)     # device arrays stay lazy until after
        all_meds.append(meds)       # every group has been dispatched
        all_drop.append(drop)
        all_corrupt.append(corrupt & ~drop)   # a lost update corrupts nothing
    with obs.span("aggregate", n_groups=len(groups),
                  aggregator=aggregator):
        if obs.enabled:             # bytes entering the reduction
            src = partials if not needs_stack else stacks
            obs.metrics.counter("aggregate.bytes").inc(sum(
                int(leaf.nbytes) for entry in src
                for leaf in jax.tree.leaves(entry[0])))
        if needs_stack:
            trees, wlist = [], []
            for stack, w_eff, drop in stacks:
                keep = np.nonzero(~drop)[0]
                if keep.size == 0:
                    continue
                trees.append(jax.tree.map(
                    lambda x: jnp.asarray(x)[keep], stack))
                wlist.append(np.asarray(w_eff, np.float64)[keep])
            if not trees:
                new_params = params
            else:
                stacked_all = (trees[0] if len(trees) == 1 else jax.tree.map(
                    lambda *xs: jnp.concatenate(xs), *trees))
                new_params = robust_combine(stacked_all, aggregator,
                                            weights=np.concatenate(wlist),
                                            base=params)
        elif mode == "sharded":
            new_params = engine.combine_group_sums(partials, fallback=params)
        else:
            new_params = _aggregate_groups(partials, fallback=params)
    with obs.span("gather", n_groups=len(groups)):
        all_loss = [np.asarray(ls) for ls in all_loss]
        for g, meds in zip(groups, all_meds):
            if meds is not None:
                meds = np.asarray(meds)
                for cid, med in zip(g.cids, meds):
                    medoids[int(cid)] = med
    stats = FleetRoundStats(
        cids=_cat(all_cids, np.int64), m=_cat(all_m, np.int64),
        budgets=_cat(all_b, np.int64),
        used_coreset=_cat(all_core, bool),
        work=_cat(all_work, np.float64),
        losses=_cat(all_loss, np.float64), medoids=medoids,
        dropped=_cat(all_drop, bool), corrupted=_cat(all_corrupt, bool))
    return new_params, stats


def run_fleet(model, clients_data: Sequence[Pytree],
              specs: Sequence[ClientSpec], cfg: FleetConfig, rounds: int,
              scheduler=None, trace: Optional[TraceConfig] = None,
              deadline: Optional[float] = None,
              straggler_pct: float = 30.0,
              test_data: Optional[Dict] = None, init_params=None,
              engine: str = "batched", eval_every: int = 1,
              faults=None, checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 0, resume: bool = False,
              verbose: bool = False) -> Dict[str, Any]:
    """Multi-round fleet driver: adaptive cohorts + batched execution.

    ``model`` is anything exposing the FLModel interface — including a
    ``repro.fed.fleet.workloads.FleetWorkload``, which is how the CNN and
    char-LM workloads run here; ``clients_data`` is the matching pytree-
    of-arrays client list (see ``CohortGroup.data``).
    ``engine`` ∈ {"batched", "loop", "sharded"}: the vmapped cohort
    programs, the per-client reference loop, or the mesh-sharded engine
    (``repro.fed.fleet.sharded``) that runs each cohort group
    data-parallel over every available device.  "sharded" silently falls
    back to "batched" on a single-device host — the two are numerically
    interchangeable.  ``scheduler`` (an ``AdaptiveParticipation`` or
    anything with its ``select`` / ``budget`` / ``observe`` /
    ``record_round`` protocol) picks each round's cohort and conditions
    coreset budgets on *observed* capability; without one, every client
    participates with nominal-capability budgets.  ``trace`` perturbs
    per-round realized durations (slowdown episodes + jitter) exactly as
    the async runtime does, which is what gives the scheduler something
    to learn.  The trace is indexed per-(client, dispatch): each client
    carries its own dispatch counter, so a client absent for some rounds
    samples exactly the entries the sync server and async event loop
    would sample for the same dispatch order.

    ``faults`` (a ``repro.fed.fleet.faults`` profile name / FaultProfile
    / None) injects dropout, churn, and Byzantine corruption as seeded
    deterministic axes; ``cfg.aggregator`` picks the (robust) combine
    rule.  Dropped clients stay in the round's trace accounting — their
    dispatch happened, only the update was lost — so fault injection
    never shifts another client's per-(client, dispatch) draws.

    ``checkpoint_dir`` + ``checkpoint_every`` save server state (params,
    round index, scheduler EWMA + RNG state, dispatch cursors, history)
    every N rounds via ``repro.checkpoint``; ``resume=True`` restores
    the latest checkpoint and continues **byte-identically** with the
    uninterrupted run — everything else (capability trace, fault draws,
    cohort grouping) is a pure function of the seed and regenerates.
    """
    if engine not in ("batched", "loop", "sharded"):
        raise ValueError(f"unknown fleet engine {engine!r} "
                         f"(expected batched | loop | sharded)")
    mode = engine
    if engine == "sharded":
        from repro.fed.fleet.sharded import ShardedFleetEngine, client_mesh
        if len(jax.devices()) > 1:
            eng = ShardedFleetEngine(model, cfg, mesh=client_mesh())
        else:       # one device: sharding is pure overhead
            eng, mode = FleetEngine(model, cfg), "batched"
    else:
        eng = FleetEngine(model, cfg)
    params = (init_params if init_params is not None
              else model.init(jax.random.PRNGKey(cfg.seed)))
    cost = resolve_cost(cfg.cost)
    if deadline is None:
        deadline = straggler_deadline(specs, cfg.epochs, straggler_pct,
                                      cost)
    cap_trace = CapabilityTrace(trace) if trace is not None else None
    eval_fn = make_eval_fn(model, test_data, 512) if test_data else None
    # per-client dispatch cursors: the CapabilityTrace is defined per
    # (client, dispatch), exactly like repro.fed.server / repro.fed.events
    tracei = DispatchTraceIndexer(len(specs), cap_trace)
    profile = get_fault_profile(faults)
    ftrace = (FaultTrace(profile, len(specs), seed=cfg.seed)
              if profile is not None and profile.any_faults() else None)
    obs = active_recorder(verbose)
    obs.run_meta(runtime="fleet", engine=mode, requested_engine=engine,
                 n_clients=len(specs), rounds=rounds,
                 deadline=float(deadline), seed=cfg.seed,
                 aggregator=cfg.aggregator,
                 faults=(profile.name if profile is not None else "none"),
                 n_devices=len(jax.devices()))

    history: List[RoundRecord] = []
    cohort_sizes: List[int] = []
    start_round = 0
    if resume and checkpoint_dir is not None:
        ck_params, ck_round = load_server_state(checkpoint_dir, like=params)
        if ck_params is not None and ck_round >= 0:
            meta = load_server_meta(checkpoint_dir) or {}
            params = ck_params
            start_round = ck_round + 1
            history = [RoundRecord(**h) for h in meta.get("history", [])]
            cohort_sizes = [int(c) for c in meta.get("cohort_sizes", [])]
            if "dispatch_counts" in meta:
                tracei.counts[:] = np.asarray(meta["dispatch_counts"],
                                              np.int64)
            if scheduler is not None and meta.get("scheduler") is not None \
                    and hasattr(scheduler, "load_state_dict"):
                scheduler.load_state_dict(meta["scheduler"])
            obs.event("resume", round=start_round,
                      checkpoint_dir=checkpoint_dir)
    for r in range(start_round, rounds):
        t0 = time.perf_counter()
        rspan = obs.span_begin("round", round=r)
        with obs.span("cohort_select", round=r):
            if scheduler is not None:
                cohort = [int(c) for c in scheduler.select()]
            else:
                cohort = list(range(len(specs)))
            if ftrace is not None and ftrace.profile.has_churn:
                mask, joins, leaves = ftrace.churn_step(r)
                cohort = [cid for cid in cohort if mask[cid]]
                if obs.enabled:
                    obs.metrics.counter("faults.churn_joins").inc(joins)
                    obs.metrics.counter("faults.churn_leaves").inc(leaves)
                    obs.metrics.gauge("faults.n_present").set(
                        int(mask.sum()))
                    obs.metrics.gauge("faults.participation_frac").set(
                        len(cohort) / max(len(specs), 1))
            if scheduler is not None:
                budgets = {cid: scheduler.budget(cid, deadline, cfg.epochs)
                           for cid in cohort}
            else:
                budgets = nominal_budgets(specs, deadline, cfg.epochs, cost)
        # fault draws key on each client's dispatch ordinal — snapshot
        # the cursors before trace_account advances them below
        ordinals = {int(c): int(tracei.counts[c]) for c in cohort}
        params, stats = run_fleet_round(eng, params, clients_data, cohort,
                                        budgets, round_seed=r, mode=mode,
                                        aggregator=cfg.aggregator,
                                        faults=ftrace,
                                        dispatch_ordinals=ordinals)
        n_fault_dropped = int(stats.dropped.sum())
        n_corrupted = int(stats.corrupted.sum())
        if obs.enabled and ftrace is not None:
            if n_fault_dropped:
                obs.metrics.counter("faults.dropped_updates").inc(
                    n_fault_dropped)
            if n_corrupted:
                obs.metrics.counter("faults.corrupted_updates").inc(
                    n_corrupted)
        durations = []
        with obs.span("trace_account", round=r):
            for cid, work in zip(stats.cids, stats.work):
                s = specs[cid]
                k = tracei.begin(cid)
                # stats.work counts sample-visits; the cost model prices
                # them into duration seconds and scheduler work units
                dur = cost.duration(work, tracei.capability(s, k))
                dur *= tracei.jitter(s, k)
                durations.append(dur)
                obs.metrics.histogram("client_busy_s").observe(dur)
                if scheduler is not None:
                    scheduler.observe(int(cid), float(cost.work_units(work)),
                                      float(dur))
        train_loss = (float(np.mean(stats.losses)) if stats.losses.size
                      else float("nan"))
        if scheduler is not None:
            scheduler.record_round(train_loss)
        # honest τ accounting (mirrors ClientResult.deadline_violated):
        # a budget clamped to 1 or a slowdown episode can still overrun τ
        n_violations = int(sum(d > deadline * (1.0 + 1e-9)
                               for d in durations))
        obs.metrics.counter("deadline_violations").inc(n_violations)
        rec = RoundRecord(
            round=r,
            sim_round_time=float(np.max(durations)) if durations else 0.0,
            client_times=[float(d) for d in durations],
            n_participants=len(cohort), n_dropped=n_fault_dropped,
            n_coreset=int(stats.used_coreset.sum()), train_loss=train_loss,
            n_violations=n_violations)
        if eval_fn and (r % eval_every == 0 or r == rounds - 1):
            with obs.span("eval", round=r):
                rec.test_acc, rec.test_loss = eval_fn(params)
        history.append(rec)
        cohort_sizes.append(len(cohort))
        obs.span_end(rspan)
        obs.event("round", runtime="fleet", engine=mode,
                  label=f"fleet/{mode}", round=r,
                  n_participants=len(cohort), n_dropped=n_fault_dropped,
                  n_corrupted=n_corrupted,
                  n_coreset=rec.n_coreset, n_violations=n_violations,
                  sim_round_time=float(rec.sim_round_time),
                  wall_time_s=time.perf_counter() - t0,
                  train_loss=float(train_loss),
                  test_acc=float(rec.test_acc),
                  test_loss=float(rec.test_loss))
        obs.event("clients", round=r,
                  cids=[int(c) for c in stats.cids],
                  durations=[float(d) for d in durations],
                  violated=[bool(d > deadline * (1.0 + 1e-9))
                            for d in durations])
        if checkpoint_dir is not None and checkpoint_every > 0 \
                and (r + 1) % checkpoint_every == 0:
            with obs.span("checkpoint", round=r):
                extra = {
                    "kind": "fleet",
                    "history": [dataclasses.asdict(h) for h in history],
                    "cohort_sizes": cohort_sizes,
                    "dispatch_counts": tracei.counts.tolist(),
                }
                if scheduler is not None and hasattr(scheduler,
                                                     "state_dict"):
                    extra["scheduler"] = scheduler.state_dict()
                save_server_state(checkpoint_dir, r, params, extra=extra)

    return {
        "params": params,
        "history": history,
        "deadline": deadline,
        "engine": engine,          # requested
        "engine_mode": mode,       # executed (sharded may fall back)
        "n_devices": len(jax.devices()),
        "cohort_sizes": cohort_sizes,
        "aggregator": cfg.aggregator,
        "faults": profile.name if profile is not None else "none",
        "strategy": "fedcore_fleet",
    }
