"""Mesh-sharded fleet engine: cohort groups data-parallel over devices.

The batched engine (``repro.fed.fleet.batched``) turned a 1000-client
round from a per-client Python loop into a handful of vmapped XLA
programs — but every one of those programs still runs on a single
device.  This module shards the *client axis* of each ``CohortGroup``
across a 1-D device mesh with ``shard_map``: local SGD, gradient-feature
extraction, and masked k-medoids (distance-free by default — the
feature-tiled selection reductions, no per-device (C, M, M) stack; see
``FleetConfig.distance_free``) all execute on ``C / n_devices`` client
lanes per device, and
the round's weighted parameter aggregation happens as a **psum tree**
inside the same program — no per-group host round-trip, no host-side
accumulation loop.

Execution contract (what makes sharding a pure performance choice):

  * the per-client arithmetic is literally the batched engine's —
    ``ShardedFleetEngine`` re-vmaps the raw ``sgd_scan`` / ``core_scan``
    programs a ``FleetEngine`` builds, so each client lane computes the
    same op sequence regardless of which device it lands on.  Medoid
    choices are bit-identical to the batched engine; aggregated params
    agree to float32 summation-order tolerance (local partial sums +
    psum vs one host tensordot);
  * groups are padded host-side to a multiple of the device count by
    repeating the last client lane with **zero aggregation weight**, so
    padding can never perturb the weighted mean, and padded medoid /
    loss lanes are sliced off before returning;
  * inputs are placed with ``NamedSharding`` over the ``"clients"`` mesh
    axis (the same placement machinery as ``repro.distributed``), and
    the weighted reduction reuses ``weighted_psum_sum`` from
    ``repro.distributed.fedavg_mesh`` — on hardware the psum lowers to a
    tree all-reduce over ICI/DCN, hierarchically when the mesh is
    factored.

``run_fleet(engine="sharded")`` routes here; on a one-device host it
falls back to the batched path (identical numbers, no shard_map
overhead).  ``benchmarks/fleet_sweep.py --device-sweep`` measures the
scaling, using XLA's forced host-platform device count on CPU CI.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.fedavg_mesh import weighted_psum_sum
from repro.fed.fleet.batched import CohortGroup, FleetConfig, FleetEngine

Pytree = Any

CLIENT_AXIS = "clients"


def client_mesh(n_devices: Optional[int] = None,
                devices: Optional[Sequence] = None) -> Mesh:
    """A 1-D mesh over the client axis (all local devices by default)."""
    devs = list(devices) if devices is not None else list(jax.devices())
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.array(devs), (CLIENT_AXIS,))


def _pad_lanes(v: np.ndarray, pad: int) -> np.ndarray:
    """Pad the leading client dim by repeating the last lane."""
    if pad == 0:
        return v
    return np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])


class ShardedFleetEngine(FleetEngine):
    """A ``FleetEngine`` whose group programs run sharded over a mesh.

    ``run_group_sharded`` executes one cohort group data-parallel over
    the ``"clients"`` mesh axis and returns the group's *weighted
    parameter sum* (already psum-reduced and replicated) — the
    server-side mean becomes one divide at the end of the round
    (``combine_group_sums``) — plus the still-sharded per-client stack
    for consumers that need individual updates (robust aggregation,
    Byzantine corruption).  The inherited ``run_group`` (batched / loop)
    still works, which is what the parity tests and the single-device
    fallback rely on.
    """

    def __init__(self, model, cfg: FleetConfig, mesh: Optional[Mesh] = None):
        super().__init__(model, cfg)
        self.mesh = mesh if mesh is not None else client_mesh()
        self.n_devices = int(self.mesh.shape[CLIENT_AXIS])
        # (k, data treedef) -> jitted shard_mapped group program; jit
        # handles shape polymorphism within one entry, and the treedef key
        # makes the cache schema-generic (any pytree-of-arrays workload)
        self._programs: Dict[Tuple[int, Any], Any] = {}

    # -- program construction --------------------------------------------

    def _program(self, k: int, data_treedef):
        return self._cached_program(self._programs, (k, data_treedef),
                                    lambda: self._build_program(k), "group")

    def _build_program(self, k: int):
        """Build the shard_mapped program for groups with budget ``k``.

        The body sees the per-device view (C_local client lanes) and is
        the batched engine's fused group body
        (``FleetEngine._make_group_body`` — one copy of the arithmetic
        across loop/batched/sharded), ending in the cross-device weighted
        psum."""
        mesh = self.mesh
        axes = (CLIENT_AXIS,)
        group_body = self._make_group_body(k)
        broadcast = self._broadcast_params

        if k == 0:
            def body(params, data, w, lane_w, idx):
                c = w.shape[0]
                p, losses, _ = group_body(params, broadcast(params, c),
                                          data, w, idx)
                part, wsum = weighted_psum_sum(lane_w, p, axes)
                return part, wsum, losses, p

            def specs(params):
                shard = P(CLIENT_AXIS)
                shard_tree = jax.tree.map(lambda _: shard, params)
                in_specs = (jax.tree.map(lambda _: P(), params), shard,
                            shard, shard, shard)
                out_specs = (jax.tree.map(lambda _: P(), params), P(),
                             shard, shard_tree)
                return in_specs, out_specs
        else:
            def body(params, data, w, lane_w, idx1, valid, steps):
                c = w.shape[0]
                p, losses, meds = group_body(params, broadcast(params, c),
                                             data, w, valid, idx1, steps)
                part, wsum = weighted_psum_sum(lane_w, p, axes)
                return part, wsum, losses, meds, p

            def specs(params):
                shard = P(CLIENT_AXIS)
                shard_tree = jax.tree.map(lambda _: shard, params)
                in_specs = (jax.tree.map(lambda _: P(), params), shard,
                            shard, shard, shard, shard, shard)
                out_specs = (jax.tree.map(lambda _: P(), params), P(),
                             shard, shard, shard_tree)
                return in_specs, out_specs

        @jax.jit
        def program(params, *args):
            in_specs, out_specs = specs(params)
            fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
            return fn(params, *args)

        return program

    # -- group execution --------------------------------------------------

    def _shard_put(self, v: np.ndarray):
        return jax.device_put(
            v, NamedSharding(self.mesh, P(CLIENT_AXIS)))

    def run_group_sharded(self, params: Pytree, group: CohortGroup,
                          weights: np.ndarray
                          ) -> Tuple[Pytree, jnp.ndarray, np.ndarray,
                                     Optional[np.ndarray], Pytree]:
        """Run one group over the mesh; returns (weighted param sum,
        weight total, per-client losses, medoid indices or None,
        per-client param stack) with padding lanes already stripped from
        losses/medoids/stack.  The stack stays sharded and lazy — it is
        only gathered when a robust aggregation rule or fault-corruption
        pass actually consumes it (the weighted-mean path uses the
        psum-reduced sum and never touches it)."""
        cfg = self.cfg
        c = group.n_clients
        pad = (-c) % self.n_devices
        # shard_put never changes the treedef, so the cache is consulted
        # before staging and the dispatch span covers the host-side
        # padding + device placement along with the program call
        program = self._program(group.k, jax.tree.structure(group.data))
        self.count_dispatch()       # same accounting point as batched:
        # one top-level jitted invocation per group, so batched and
        # sharded runs of a cohort report identical dispatch counts
        name = "local_sgd" if group.k == 0 else "coreset_group"

        # outputs stay device-resident (lazy): materializing here would
        # block each group's program before the next one is dispatched,
        # serializing the mesh — the round driver converts after every
        # group has been enqueued
        with self._dispatch_span(name, program, k=group.k, n_clients=c,
                                 sharded=True):
            lane_w = np.concatenate(
                [np.asarray(weights, np.float32),
                 np.zeros(pad, np.float32)])
            data = jax.tree.map(
                lambda v: self._shard_put(_pad_lanes(np.asarray(v), pad)),
                group.data)
            w = self._shard_put(
                _pad_lanes(group.valid.astype(np.float32), pad))
            lane_w = self._shard_put(lane_w)
            m_pad = group.valid.shape[1]
            t_full = cfg.epochs * (m_pad // cfg.batch_size)
            idx_all = group.perms.reshape(c, t_full, cfg.batch_size)
            if group.k == 0:
                idx = self._shard_put(_pad_lanes(idx_all, pad))
                part, wsum, losses, stack = program(params, data, w,
                                                    lane_w, idx)
                stack = jax.tree.map(lambda x: x[:c], stack)
                return part, wsum, losses[:c], None, stack
            idx1 = self._shard_put(
                _pad_lanes(idx_all[:, : m_pad // cfg.batch_size], pad))
            valid = self._shard_put(_pad_lanes(group.valid, pad))
            steps = self._shard_put(
                np.zeros((c + pad, max(cfg.epochs - 1, 1)), np.float32))
            part, wsum, losses, meds, stack = program(params, data, w,
                                                      lane_w, idx1, valid,
                                                      steps)
            stack = jax.tree.map(lambda x: x[:c], stack)
        return part, wsum, losses[:c], meds[:c], stack

    def combine_group_sums(self, partials: List[Tuple[Pytree, jnp.ndarray]],
                           fallback: Pytree) -> Pytree:
        """Σ_g (weighted param sum) / Σ_g (weight total), device-resident.

        Groups are visited in the deterministic sorted-key order
        ``make_cohort_groups`` emits, so the reduction is order-stable.
        An empty cohort (or all-zero weights) returns ``fallback`` — the
        same no-op semantics as ``_aggregate_groups``."""
        if not partials:
            return fallback
        acc, total = partials[0]
        for part, wsum in partials[1:]:
            acc = jax.tree.map(jnp.add, acc, part)
            total = total + wsum
        if float(total) <= 0.0:
            return fallback
        return jax.tree.map(lambda x: x / total, acc)
