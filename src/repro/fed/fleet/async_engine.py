"""Event-driven async fleet engine: micro-batched cohort rounds at scale.

The async runtime (``repro.fed.events``) and the fleet engines
(``repro.fed.fleet.batched`` / ``sharded``) were the repo's two best
subsystems — and mutually exclusive: the event loop steps one client per
completion (a Python-rate ceiling of a few hundred clients), while the
fleet engines only run barrier-synchronous rounds.  This module is their
convergence, the ROADMAP "async x fleet" item:

  * the virtual-clock ``EventQueue`` orders DISPATCH/COMPLETE events
    exactly as in ``repro.fed.events`` — but a completion does **not**
    train anything.  It lands in a server-side **buffer** (FedBuff-style
    buffered-K, Nguyen et al. 2022);
  * when the buffer holds ``buffer_k`` completions, the engine
    **micro-batches** them into padded same-shape cohort groups
    (``make_cohort_groups`` — the exact grouping, padding, and seeded
    per-client permutation logic of the sync fleet path) and dispatches
    the fused single-jit donated group programs (``_make_group_body``
    via ``FleetEngine`` / ``ShardedFleetEngine``) from inside the event
    loop.  No per-client Python stepping: jitted-program dispatches
    scale with the number of distinct (M, k) group shapes per flush,
    not with clients;
  * each buffered update carries an exact **staleness** (server
    versions — i.e. flushes — between its dispatch and its merge) and
    the global params it was dispatched from stay pinned (refcounted)
    until every client trained from them has been merged, so groups
    train from their true dispatch-time snapshots;
  * the server-side merge goes through a staleness-aware **merge rule**
    — the vectorized form of the streaming ``repro.fed.aggregators``:
    every rule reduces to ``new = c_w * w_global + sum_i c_i * w_i``
    (plus dispatch-snapshot terms for delayed gradients), which is one
    ``weighted_param_sum`` per group on the batched path and one
    mesh-reduced ``weighted_psum_sum`` per group on the sharded path —
    the host-side per-update aggregation loop is gone;
  * the FLANP/EWMA scheduler (``AdaptiveParticipation``) plugs into the
    same ``eligible_mask`` / ``observe`` / ``budget`` / ``record_round``
    protocol: dispatch waves weight clients by its mask, every
    completion feeds its capability EWMA, and per-dispatch coreset
    budgets come from *observed* capability between flushes.

Client training time is accounted analytically (work units / effective
capability x trace jitter), exactly like the sync fleet driver — so the
event schedule, and therefore the event log, is a pure function of
``(seed, specs, trace, scheduler state)`` and byte-identical across the
batched / loop / sharded execution modes.  That is the determinism
contract the parity tests pin: grouping and execution mode are pure
performance choices.

Semantics note — **staleness is measured in flushes** (server versions),
matching how every merge rule discounts it.  ``FedAsyncMerge`` applies
its per-update sequential mixing in closed form over the buffer, so a
flush of K updates reproduces K sequential ``FedAsync.apply`` calls
with those staleness values exactly.
"""
from __future__ import annotations

import dataclasses
import functools
import time as _time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (load_server_meta, load_server_state,
                              save_server_state)
from repro.fed.cost import resolve_cost
from repro.fed.aggregators import (ROBUST_METHODS, DelayedGradient, FedAsync,
                                   FedBuff, RobustAggregate,
                                   polynomial_staleness, robust_combine)
from repro.fed.events import COMPLETE, DISPATCH, Event, EventQueue
from repro.fed.fleet.batched import (FleetConfig, FleetEngine, _floor_pow4,
                                     make_cohort_groups, weighted_param_sum)
from repro.fed.fleet.faults import (FaultTrace, corrupt_stacked,
                                    get_fault_profile)
from repro.fed.server import RoundRecord, make_eval_fn
from repro.fed.simulator import (CapabilityTrace, ClientSpec,
                                 DispatchTraceIndexer, TraceConfig,
                                 straggler_deadline)
from repro.obs import active_recorder
from repro.utils.tree import tree_add, tree_scale

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AsyncFleetConfig:
    """Event-loop + local-training knobs for the async fleet engine.

    One applied server update = one buffer **flush** (a micro-batched
    merge of ``buffer_k`` completions), so ``max_updates`` counts
    flushes — the direct analogue of rounds, not of single-client
    updates as in ``AsyncFLConfig``."""
    max_updates: int = 20         # applied flushes (server versions)
    max_virtual_time: Optional[float] = None  # stop past this clock value
    buffer_k: int = 8             # completions per merge (FedBuff K)
    concurrency: int = 16         # in-flight client cap
    epochs: int = 2               # E
    batch_size: int = 8
    lr: float = 0.05
    use_kernel: Optional[bool] = None   # tri-state Pallas switch
    # distance-free selection (see FleetConfig.distance_free): default on,
    # False keeps the materializing (C, M, M) path as the A/B baseline;
    # materialize_below is the adaptive small-M cutover
    distance_free: bool = True
    materialize_below: int = 256
    max_sweeps: int = 25
    weight_by_samples: bool = True
    straggler_pct: float = 30.0
    deadline: Optional[float] = None
    eval_every: int = 1           # eval every Nth flush
    seed: int = 0
    trace: Optional[TraceConfig] = None
    # per-sample step cost (repro.fed.cost.WorkloadCostModel or scalar;
    # None = legacy samples-cost-1.0) — prices budgets, the derived
    # deadline, and realized dispatch durations
    cost: Any = None

    def fleet_config(self) -> FleetConfig:
        """The grouping/training config shared with the sync fleet path
        (same perms, same padding, same group programs)."""
        return FleetConfig(epochs=self.epochs, batch_size=self.batch_size,
                           lr=self.lr, use_kernel=self.use_kernel,
                           distance_free=self.distance_free,
                           materialize_below=self.materialize_below,
                           max_sweeps=self.max_sweeps,
                           weight_by_samples=self.weight_by_samples,
                           seed=self.seed, cost=self.cost)


# ---------------------------------------------------------------------------
# merge rules: the streaming aggregators, vectorized over a buffer
# ---------------------------------------------------------------------------

class AsyncMergeRule:
    """One buffer flush as a linear combination.

    ``coefficients(staleness, n_samples)`` returns ``(c, c_w)`` such
    that the merged params are

        new = c_w * w_global + sum_i c_i * w_i          (use_base=False)
        new = w_global + sum_i c_i * (w_i - base_i)     (use_base=True)

    with ``w_i`` the buffered client params in **arrival order** and
    ``base_i`` the dispatch-time global snapshot of update i.  The
    engine evaluates the sums as one fused ``weighted_param_sum`` (or
    mesh ``weighted_psum_sum``) per cohort group, so the merge itself
    never loops over clients host-side."""
    name = "base"
    use_base = False    # True: coefficients weight deltas from dispatch
    robust = False      # True: flush goes through robust_combine instead

    def coefficients(self, staleness: np.ndarray, n_samples: np.ndarray
                     ) -> Tuple[np.ndarray, float]:
        raise NotImplementedError


class FedBuffMerge(AsyncMergeRule):
    """FedBuff (Nguyen et al., 2022): staleness-discounted weighted mean
    of the buffer, mixed in with server learning-rate eta.  Identical to
    ``aggregators.FedBuff`` on a full buffer — and on a partial one via
    the engine's final drain."""
    name = "fedbuff"

    def __init__(self, staleness_exponent: float = 0.5,
                 server_lr: float = 1.0, weight_by_samples: bool = False):
        if not 0.0 < server_lr <= 1.0:
            raise ValueError(f"server_lr must be in (0, 1], got {server_lr}")
        self.staleness_exponent = staleness_exponent
        self.server_lr = server_lr
        self.weight_by_samples = weight_by_samples

    def coefficients(self, staleness, n_samples):
        w = (1.0 + staleness.astype(np.float64)) ** -self.staleness_exponent
        if self.weight_by_samples:
            w = w * n_samples.astype(np.float64)
        c = self.server_lr * w / w.sum()
        return c, 1.0 - self.server_lr


class FedAsyncMerge(AsyncMergeRule):
    """FedAsync (Xie et al., 2019) sequential mixing in closed form.

    Applying w <- (1 - a_i) w + a_i w_i for i = 1..K telescopes to

        c_w = prod_j (1 - a_j),    c_i = a_i * prod_{j>i} (1 - a_j)

    so one vectorized flush reproduces K sequential ``FedAsync.apply``
    calls bit-for... well, to float32 summation tolerance."""
    name = "fedasync"

    def __init__(self, mixing: float = 0.6, staleness_exponent: float = 0.5):
        if not 0.0 < mixing <= 1.0:
            raise ValueError(f"mixing must be in (0, 1], got {mixing}")
        self.mixing = mixing
        self.staleness_exponent = staleness_exponent

    def coefficients(self, staleness, n_samples):
        a = self.mixing * (1.0 + staleness.astype(np.float64)
                           ) ** -self.staleness_exponent
        keep = np.cumprod((1.0 - a)[::-1])[::-1]   # keep[i] = prod_{j>=i}
        tail = np.concatenate([keep[1:], [1.0]])   # tail[i] = prod_{j>i}
        return a * tail, float(keep[0])


class DelayedGradientMerge(AsyncMergeRule):
    """Staleness-discounted delayed deltas (arXiv 2102.06329):
    w <- w + sum_i eta * (1 + t_i)^{-a} * (w_i - base_i)."""
    name = "delayed_grad"
    use_base = True

    def __init__(self, server_lr: float = 1.0,
                 staleness_exponent: float = 0.5):
        self.server_lr = server_lr
        self.staleness_exponent = staleness_exponent

    def coefficients(self, staleness, n_samples):
        c = self.server_lr * (1.0 + staleness.astype(np.float64)
                              ) ** -self.staleness_exponent
        return c, 1.0


class RobustMerge(AsyncMergeRule):
    """Byzantine-robust flush: instead of the linear form, the buffered
    client params are stacked and combined with one of the robust
    estimators from ``repro.fed.aggregators`` (trimmed mean / median /
    Krum / multi-Krum / norm-clip), then mixed into the global params
    with ``server_lr``.  This is the async analogue of
    ``RobustAggregate`` — the estimator sees one buffer flush the way
    the sync rule sees one round."""
    name = "robust"
    robust = True

    def __init__(self, method: str, server_lr: float = 1.0,
                 weight_by_samples: bool = True, trim_frac: float = 0.1,
                 n_byzantine: Optional[int] = None):
        if method not in ROBUST_METHODS:
            raise ValueError(f"unknown robust merge method {method!r} "
                             f"(expected one of {sorted(ROBUST_METHODS)})")
        if not 0.0 < server_lr <= 1.0:
            raise ValueError(f"server_lr must be in (0, 1], got {server_lr}")
        self.method = method
        self.name = method
        self.server_lr = server_lr
        self.weight_by_samples = weight_by_samples
        self.trim_frac = trim_frac
        self.n_byzantine = n_byzantine

    def coefficients(self, staleness, n_samples):
        # never used on the robust path — zeros make any accidental
        # linear evaluation a no-op that keeps the base params
        return np.zeros(len(staleness), np.float64), 1.0


ASYNC_MERGES = {
    "fedbuff": FedBuffMerge,
    "fedasync": FedAsyncMerge,
    "delayed_grad": DelayedGradientMerge,
    **{m: functools.partial(RobustMerge, m) for m in ROBUST_METHODS},
}


def as_merge_rule(aggregator) -> AsyncMergeRule:
    """Coerce an aggregator spec into a merge rule.

    Accepts ``None`` (FedBuff defaults), a registry name, an
    ``AsyncMergeRule`` instance, or one of the streaming
    ``repro.fed.aggregators`` instances (``FedBuff`` / ``FedAsync`` /
    ``DelayedGradient``), whose hyperparameters carry over — so
    ``run_scenario`` callers can pass the same aggregator object to the
    async and async_fleet runtimes."""
    if aggregator is None:
        return FedBuffMerge()
    if isinstance(aggregator, AsyncMergeRule):
        return aggregator
    if isinstance(aggregator, str):
        try:
            return ASYNC_MERGES[aggregator]()
        except KeyError:
            raise ValueError(
                f"unknown async merge rule {aggregator!r} "
                f"(expected one of {sorted(ASYNC_MERGES)})") from None
    if isinstance(aggregator, FedBuff):
        return FedBuffMerge(staleness_exponent=aggregator.staleness_exponent,
                            server_lr=aggregator.server_lr,
                            weight_by_samples=aggregator.weight_by_samples)
    if isinstance(aggregator, FedAsync):
        return FedAsyncMerge(mixing=aggregator.mixing,
                             staleness_exponent=aggregator.staleness_exponent)
    if isinstance(aggregator, DelayedGradient):
        return DelayedGradientMerge(
            server_lr=aggregator.server_lr,
            staleness_exponent=aggregator.staleness_exponent)
    if isinstance(aggregator, RobustAggregate):
        return RobustMerge(aggregator.method,
                           weight_by_samples=aggregator.weight_by_samples,
                           trim_frac=aggregator.trim_frac,
                           n_byzantine=aggregator.n_byzantine)
    raise TypeError(f"cannot derive an async merge rule from "
                    f"{type(aggregator).__name__}")


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Buffered:
    """One completed-but-unmerged client contribution."""
    cid: int
    v0: int             # server version (flush count) at dispatch
    budget: int         # raw coreset budget b (>= m means full-set)
    k: int              # quantized group budget (0 = full-set)
    m: int              # client dataset size
    work: float         # samples visited (analytic)
    duration: float     # realized virtual training time
    staleness: int      # version - v0 at arrival (== at merge; see module doc)
    dispatch_ix: int = 0    # per-client dispatch ordinal (fault stream key)


def run_async_fleet(model, clients_data: Sequence[Pytree],
                    specs: Sequence[ClientSpec], cfg: AsyncFleetConfig,
                    aggregator=None, scheduler=None,
                    test_data: Optional[Dict] = None, init_params=None,
                    engine: str = "batched", eval_batch: int = 512,
                    engine_obj=None, faults=None,
                    checkpoint_dir: Optional[str] = None,
                    checkpoint_every: int = 0, resume: bool = False,
                    verbose: bool = False) -> Dict[str, Any]:
    """Drive the fleet group programs through the async event loop.

    ``engine`` selects the execution model for the per-flush group
    programs: ``"batched"`` (vmapped, one jitted dispatch per group),
    ``"loop"`` (the per-client reference — same arithmetic, Python-rate
    dispatch; parity gate only), or ``"sharded"`` (groups run
    data-parallel over the client mesh and each group's coefficient-
    weighted parameter sum arrives already psum-reduced).  On a
    one-device host ``"sharded"`` falls back to ``"batched"``.

    ``faults`` injects seeded deterministic failure modes
    (``repro.fed.fleet.faults``): dropout kills a completion *after* its
    DISPATCH was accounted (the dispatch-trace cursor still advances, so
    surviving clients' capability/jitter draws are unchanged), churn
    masks the dispatch wave, and Byzantine corruption rewrites a fixed
    client subset's updates before the merge.  ``checkpoint_dir`` +
    ``checkpoint_every`` snapshot the full event-loop state every Nth
    flush; ``resume=True`` restores the latest snapshot and continues
    byte-identically with the uninterrupted run.

    Returns the ``run_federated_async`` result shape (params / history /
    event_log / telemetry) plus fleet accounting (group-program dispatch
    counts, buffer occupancy)."""
    if engine not in ("batched", "loop", "sharded"):
        raise ValueError(f"unknown async fleet engine {engine!r} "
                         f"(expected batched | loop | sharded)")
    wall0 = _time.perf_counter()
    n = len(specs)
    if n == 0:
        raise ValueError("run_async_fleet needs at least one client")
    mode = engine
    if engine_obj is not None:
        # caller-supplied engine (warm program cache across runs — the
        # benchmark's repeated-measurement path); its config must match
        eng = engine_obj
        if engine == "sharded" and len(jax.devices()) <= 1:
            mode = "batched"
    elif engine == "sharded":
        from repro.fed.fleet.sharded import ShardedFleetEngine, client_mesh
        if len(jax.devices()) > 1:
            eng = ShardedFleetEngine(model, cfg.fleet_config(),
                                     mesh=client_mesh())
        else:       # one device: sharding is pure overhead
            eng, mode = FleetEngine(model, cfg.fleet_config()), "batched"
    else:
        eng = FleetEngine(model, cfg.fleet_config())
    fcfg = eng.cfg
    rule = as_merge_rule(aggregator)
    rng = np.random.default_rng(cfg.seed)
    params = (init_params if init_params is not None
              else model.init(jax.random.PRNGKey(cfg.seed)))
    cost = resolve_cost(cfg.cost)
    deadline = cfg.deadline
    if deadline is None:
        deadline = straggler_deadline(specs, cfg.epochs, cfg.straggler_pct,
                                      cost)
    trace = CapabilityTrace(cfg.trace) if cfg.trace is not None else None
    eval_fn = make_eval_fn(model, test_data, eval_batch) if test_data else None
    profile = get_fault_profile(faults)
    ftrace = (FaultTrace(profile, n, seed=cfg.seed)
              if profile is not None and profile.any_faults() else None)
    corruption = ftrace is not None and profile.has_corruption
    fault_name = profile.name if profile is not None else "none"

    # a buffer larger than the in-flight cap could never fill; clamp both
    # to the fleet size so tiny fleets still make progress
    concurrency = min(cfg.concurrency, n)
    buffer_k = max(1, min(cfg.buffer_k, concurrency))

    sizes = np.array([s.m for s in specs], np.float64)
    busy = np.zeros(n, bool)
    busy_time = np.zeros(n)
    tracei = DispatchTraceIndexer(n, trace)
    obs = active_recorder(verbose)
    obs.run_meta(runtime="async_fleet", engine=mode,
                 requested_engine=engine, aggregator=rule.name,
                 faults=fault_name, n_clients=n,
                 max_updates=cfg.max_updates,
                 buffer_k=buffer_k, concurrency=concurrency,
                 deadline=float(deadline), seed=cfg.seed,
                 n_devices=len(jax.devices()))

    queue = EventQueue()
    event_log: List[str] = []
    history: List[RoundRecord] = []
    staleness_log: List[int] = []
    occupancy_log: List[int] = []

    buffer: List[_Buffered] = []
    # dispatch-time params, pinned until every client trained from a
    # version has been merged: version -> [params, refcount]
    params_by_version: Dict[int, List[Any]] = {}
    pending: Dict[int, _Buffered] = {}   # cid -> in-flight contribution

    version = 0
    applied = 0
    now = 0.0
    merged_total = 0
    violations_total = 0
    partial_flushes = 0
    dropped_total = 0       # fault-dropped completions (update lost)
    corrupted_total = 0     # Byzantine-rewritten lanes merged
    rec_dropped = 0         # drops inside the current flush window
    rec_start = 0.0
    rec_wall0 = _time.perf_counter()
    # like repro.fed.events: the "round" is a flush-to-flush record
    # window, so round/buffer_fill spans open and close at window
    # boundaries rather than around a lexical block
    round_span = None
    fill_span = None

    def dispatch_wave(t: float) -> int:
        """Refill free slots with one weighted no-replacement draw.

        Waves run only at t=0 and after a flush (never per-completion),
        so a client can hold at most one spot per buffer and the wave is
        one ``rng.choice`` regardless of fleet size.  Under churn, the
        present-mask at the current server version zeroes absent
        clients' sampling weight — identical to the sync fleet's
        cohort-filter semantics, indexed by flush instead of round."""
        free = concurrency - int(busy.sum())
        if free <= 0:
            return 0
        p = sizes * ~busy
        if scheduler is not None:
            p = p * scheduler.eligible_mask()
        if ftrace is not None and ftrace.profile.has_churn:
            mask, joins, leaves = ftrace.churn_step(version)
            p = p * mask
            obs.metrics.counter("faults.churn_joins").inc(joins)
            obs.metrics.counter("faults.churn_leaves").inc(leaves)
            obs.metrics.gauge("faults.n_present").set(int(mask.sum()))
        total = p.sum()
        if total <= 0.0:
            return 0
        s = min(free, int((p > 0).sum()))
        picks = rng.choice(n, size=s, replace=False, p=p / total)
        for cid in np.sort(picks):
            busy[cid] = True
            queue.push(t, DISPATCH, int(cid), version)
        slot = params_by_version.setdefault(version, [params, 0])
        slot[1] += s
        return s

    def merge_buffer(t: float, partial: bool) -> None:
        """Flush: micro-batch the buffer into cohort groups, run the
        fused group programs from each dispatch snapshot, and merge via
        the rule's linear form.  ``partial=True`` marks a final drain of
        an under-filled buffer (tail updates are merged, not dropped)."""
        nonlocal params, version, applied, merged_total, violations_total
        nonlocal partial_flushes, corrupted_total, rec_dropped
        nonlocal rec_start, rec_wall0, round_span, fill_span
        obs.span_end(fill_span)
        buf, buffer[:] = list(buffer), []
        stal = np.array([e.staleness for e in buf], np.int64)
        msz = np.array([e.m for e in buf], np.int64)
        c, c_w = rule.coefficients(stal, msz)
        coef = {e.cid: float(ci) for e, ci in zip(buf, c)}
        # robust rules and Byzantine corruption need the per-client
        # parameter stacks; the linear rules only need the weighted sums
        use_stack = rule.robust or corruption
        dix = {e.cid: e.dispatch_ix for e in buf}
        msz_by_cid = {e.cid: e.m for e in buf}

        # group by dispatch snapshot, then by (M, k) shape within it —
        # every client trains from the params it was actually handed
        by_v0: Dict[int, List[_Buffered]] = {}
        for e in buf:
            by_v0.setdefault(e.v0, []).append(e)
        with obs.span("cohort_build", n_clients=len(buf),
                      n_versions=len(by_v0)):
            grouped = []
            for v0 in sorted(by_v0):
                entries = by_v0[v0]
                groups = make_cohort_groups(
                    clients_data, [e.cid for e in entries],
                    {e.cid: e.budget for e in entries}, fcfg,
                    round_seed=len(history))
                grouped.append((v0, groups))

        # one fused program per group; each contributes its coefficient-
        # weighted parameter sum (psum-reduced on the sharded mesh, one
        # tensordot on the batched path) — no host-side client loop
        acc = None
        stack_parts = []        # (per-client stack, cids) — robust path
        n_corrupted = 0
        losses_by_cid: Dict[int, float] = {}
        loss_parts = []
        with obs.span("dispatch", n_clients=len(buf),
                      n_groups=sum(len(gs) for _, gs in grouped)):
            for v0, groups in grouped:
                base = params_by_version[v0][0]
                for g in groups:
                    w = np.array([coef[int(cid)] for cid in g.cids],
                                 np.float64)
                    part = None
                    if mode == "sharded":
                        part, _, losses, _, p = eng.run_group_sharded(
                            base, g, w)
                    else:
                        p, losses, _ = eng.run_group(
                            params=base, group=g,
                            batched=(mode == "batched"))
                    if use_stack:
                        if corruption:
                            ords = np.array(
                                [dix[int(cid)] for cid in g.cids], np.int64)
                            p, nc = corrupt_stacked(p, base, g.cids, ords,
                                                    ftrace)
                            n_corrupted += nc
                        if rule.robust:
                            part = None
                            stack_parts.append((p, np.asarray(g.cids)))
                        else:       # linear rule over corrupted lanes
                            part = weighted_param_sum(p, w)
                    elif part is None:
                        part = weighted_param_sum(p, w)
                    if part is not None:
                        acc = part if acc is None else tree_add(acc, part)
                    loss_parts.append((g.cids, losses))
        with obs.span("aggregate", n_clients=len(buf), n_versions=len(by_v0),
                      partial=partial):
            if rule.robust:
                # concatenate the group stacks (deterministic group
                # order) and hand the full flush to the estimator
                stacked, cid_order = stack_parts[0]
                cid_order = [cid_order]
                for p2, cids2 in stack_parts[1:]:
                    stacked = jax.tree.map(
                        lambda a, b: jnp.concatenate([a, b]), stacked, p2)
                    cid_order.append(cids2)
                order = np.concatenate(cid_order)
                wts = (np.array([msz_by_cid[int(i)] for i in order],
                                np.float64)
                       if rule.weight_by_samples else None)
                combined = robust_combine(
                    stacked, rule.method, weights=wts, base=params,
                    trim_frac=rule.trim_frac, n_byzantine=rule.n_byzantine)
                lr = rule.server_lr
                new = (combined if lr >= 1.0 else
                       tree_add(tree_scale(params, 1.0 - lr),
                                tree_scale(combined, lr)))
            elif rule.use_base:   # w + sum c_i w_i - sum_v (sum_i c_i) base_v
                new = tree_add(params, acc)
                for v0, _ in grouped:
                    bsum = float(sum(coef[e.cid] for e in by_v0[v0]))
                    new = tree_add(new, tree_scale(
                        params_by_version[v0][0], -bsum))
            elif c_w == 0.0:
                new = acc
            else:
                new = tree_add(tree_scale(params, c_w), acc)
            params = new
        corrupted_total += n_corrupted
        if n_corrupted:
            obs.metrics.counter("faults.corrupted_updates").inc(n_corrupted)
        with obs.span("gather", n_clients=len(buf)):
            # materializing here blocks on the (lazily dispatched) group
            # programs, so the wall time lands in an accounted phase
            for cids, losses in loss_parts:
                for cid, ls in zip(cids, np.asarray(losses)):
                    losses_by_cid[int(cid)] = float(ls)

        # unpin dispatch snapshots: decrement every merged ref first,
        # then prune, so duplicate v0s in one flush can't double-free
        for e in buf:
            params_by_version[e.v0][1] -= 1
        for v in [v for v, slot in params_by_version.items()
                  if slot[1] <= 0]:
            del params_by_version[v]

        version += 1
        applied += 1
        merged_total += len(buf)
        if partial:
            partial_flushes += 1
            obs.metrics.counter("aggregator.partial_flushes").inc()
        n_viol = sum(e.duration > deadline * (1.0 + 1e-9) for e in buf)
        violations_total += n_viol
        obs.metrics.counter("deadline_violations").inc(n_viol)
        train_loss = (float(np.mean([losses_by_cid[e.cid] for e in buf]))
                      if buf else float("nan"))
        if scheduler is not None:
            scheduler.record_round(train_loss)
        rec = RoundRecord(
            round=len(history), sim_round_time=t - rec_start,
            client_times=[float(e.duration) for e in buf],
            n_participants=len(buf), n_dropped=rec_dropped,
            n_coreset=sum(e.k > 0 for e in buf),
            train_loss=train_loss, n_violations=n_viol)
        if eval_fn and (len(history) % cfg.eval_every == 0
                        or applied >= cfg.max_updates or partial):
            with obs.span("eval", round=rec.round):
                rec.test_acc, rec.test_loss = eval_fn(params)
        history.append(rec)
        obs.span_end(round_span)
        obs.event("round", runtime="async_fleet", engine=mode,
                  label=f"async_fleet/{rule.name}", round=rec.round,
                  n_participants=rec.n_participants, n_dropped=rec_dropped,
                  n_corrupted=n_corrupted,
                  n_coreset=rec.n_coreset, n_violations=n_viol,
                  sim_round_time=float(rec.sim_round_time),
                  wall_time_s=_time.perf_counter() - rec_wall0,
                  train_loss=float(rec.train_loss),
                  test_acc=float(rec.test_acc),
                  test_loss=float(rec.test_loss),
                  applied=applied, t_virtual=float(t),
                  buffered=len(buf), partial=partial,
                  mean_staleness=float(stal.mean()) if len(buf) else 0.0)
        obs.event("clients", round=rec.round,
                  cids=[int(e.cid) for e in buf],
                  durations=[float(e.duration) for e in buf],
                  violated=[bool(e.duration > deadline * (1.0 + 1e-9))
                            for e in buf])
        rec_start = t
        rec_wall0 = _time.perf_counter()
        rec_dropped = 0
        # snapshot *between* windows: the flush is fully accounted and
        # the continuation wave has not fired yet, so a resumed run
        # replays the wave + next window byte-identically
        if (checkpoint_dir is not None and checkpoint_every > 0
                and not partial and applied % checkpoint_every == 0):
            save_checkpoint(t)
        if applied < cfg.max_updates and not partial:
            # the run continues: open the next flush window
            round_span = obs.span_begin("round", round=len(history))
            with obs.span("dispatch_wave", round=len(history)):
                dispatch_wave(t)
            fill_span = obs.span_begin("buffer_fill", round=len(history))
        else:
            # terminal flush — no trailing sliver of a window
            round_span = fill_span = None

    def save_checkpoint(t: float) -> None:
        """Snapshot the complete event-loop state.

        Params plus every pinned dispatch snapshot go into one npz
        pytree; the virtual clock (queue heap + push sequence), pending
        and buffered contributions, logs, counters, RNG bit-generator
        state, and scheduler state go into the JSON meta sidecar — a
        resumed run replays the continuation wave and every later event
        byte-identically with the uninterrupted run."""
        with obs.span("checkpoint", round=len(history)):
            tree = {"params": params,
                    "versions": {str(v): slot[0]
                                 for v, slot in params_by_version.items()}}
            meta = {
                "kind": "async_fleet",
                "version": version, "applied": applied, "now": float(t),
                "merged_total": merged_total,
                "violations_total": violations_total,
                "partial_flushes": partial_flushes,
                "dropped_total": dropped_total,
                "corrupted_total": corrupted_total,
                "rec_start": float(rec_start),
                "seq": int(queue._seq),
                "heap": [[float(ht), int(hs), he.kind, int(he.cid),
                          int(he.version), float(he.duration)]
                         for ht, hs, he in queue._heap],
                "event_log": list(event_log),
                "history": [dataclasses.asdict(r) for r in history],
                "staleness_log": [int(x) for x in staleness_log],
                "occupancy_log": [int(x) for x in occupancy_log],
                "busy": busy.tolist(),
                "busy_time": busy_time.tolist(),
                "pending": {str(cid): dataclasses.asdict(e)
                            for cid, e in pending.items()},
                "buffer": [dataclasses.asdict(e) for e in buffer],
                "refcounts": {str(v): int(slot[1])
                              for v, slot in params_by_version.items()},
                "dispatch_counts": tracei.counts.tolist(),
                "rng_state": rng.bit_generator.state,
            }
            if scheduler is not None and hasattr(scheduler, "state_dict"):
                meta["scheduler"] = scheduler.state_dict()
            save_server_state(checkpoint_dir, applied, tree, extra=meta)

    if resume and checkpoint_dir is not None:
        tree, _ = load_server_state(checkpoint_dir)
        meta = load_server_meta(checkpoint_dir)
        if tree is not None and meta is not None \
                and meta.get("kind") == "async_fleet":
            params = tree["params"]
            refc = meta["refcounts"]
            params_by_version = {int(v): [pv, int(refc[v])]
                                 for v, pv in tree["versions"].items()}
            version = int(meta["version"])
            applied = int(meta["applied"])
            now = float(meta["now"])
            merged_total = int(meta["merged_total"])
            violations_total = int(meta["violations_total"])
            partial_flushes = int(meta["partial_flushes"])
            dropped_total = int(meta["dropped_total"])
            corrupted_total = int(meta["corrupted_total"])
            rec_start = float(meta["rec_start"])
            event_log[:] = [str(s) for s in meta["event_log"]]
            history[:] = [RoundRecord(**h) for h in meta["history"]]
            staleness_log[:] = [int(x) for x in meta["staleness_log"]]
            occupancy_log[:] = [int(x) for x in meta["occupancy_log"]]
            busy[:] = np.asarray(meta["busy"], bool)
            busy_time[:] = np.asarray(meta["busy_time"], np.float64)
            pending.clear()
            pending.update({int(k): _Buffered(**v)
                            for k, v in meta["pending"].items()})
            buffer[:] = [_Buffered(**v) for v in meta["buffer"]]
            # the saved heap list already satisfies the heap invariant
            queue._heap[:] = [
                (ht, hs, Event(ht, hs, kind, int(cid), int(ver), dur))
                for ht, hs, kind, cid, ver, dur in meta["heap"]]
            queue._seq = int(meta["seq"])
            tracei.counts[:] = np.asarray(meta["dispatch_counts"], np.int64)
            rng.bit_generator.state = meta["rng_state"]
            if (scheduler is not None and "scheduler" in meta
                    and hasattr(scheduler, "load_state_dict")):
                scheduler.load_state_dict(meta["scheduler"])
            obs.event("resume", runtime="async_fleet", round=len(history),
                      applied=applied, checkpoint_dir=str(checkpoint_dir))

    # open the first flush window.  On a fresh start this is round 0 at
    # t=0; on resume it replays exactly the continuation ``merge_buffer``
    # would have run after the checkpointed flush (same wave, same RNG
    # draw, same event sequence numbers).
    round_span = obs.span_begin("round", round=len(history))
    with obs.span("dispatch_wave", round=len(history)):
        dispatch_wave(now)
    fill_span = obs.span_begin("buffer_fill", round=len(history))
    unprocessed = []    # events past a max_virtual_time cutoff

    while len(queue) and applied < cfg.max_updates:
        ev = queue.pop()
        if (cfg.max_virtual_time is not None
                and ev.time > cfg.max_virtual_time):
            unprocessed.append(ev)
            break
        now = ev.time
        event_log.append(ev.fmt())

        if ev.kind == DISPATCH:
            spec = specs[ev.cid]
            k_idx = tracei.begin(ev.cid)
            c_eff = tracei.capability(spec, k_idx)
            obs.metrics.counter("dispatches").inc()
            # budget under *realized* capability: a device in a slowdown
            # episode plans a smaller coreset, exactly as the sync
            # FedCore client would at dispatch time.  The cost model
            # prices each sample-visit (legacy unit cost when unset).
            if scheduler is not None:
                b = int(scheduler.budget(ev.cid, deadline, cfg.epochs))
            elif cost.needs_coreset(spec.m, c_eff, deadline, cfg.epochs):
                b = cost.budget(spec.m, c_eff, deadline, cfg.epochs)
            else:
                b = spec.m
            kq = 0 if b >= spec.m else _floor_pow4(b)
            work = float(cfg.epochs * spec.m if kq == 0
                         else spec.m + (cfg.epochs - 1) * kq)
            duration = cost.duration(work, c_eff) * tracei.jitter(spec,
                                                                  k_idx)
            pending[ev.cid] = _Buffered(
                cid=ev.cid, v0=ev.version, budget=b, k=kq, m=spec.m,
                work=work, duration=duration, staleness=0,
                dispatch_ix=k_idx)
            queue.push(now + duration, COMPLETE, ev.cid, ev.version,
                       duration)
            continue

        # COMPLETE: buffer the contribution; train only at flush time
        e = pending.pop(ev.cid)
        busy[ev.cid] = False
        busy_time[ev.cid] += ev.duration
        obs.metrics.histogram("client_busy_s").observe(ev.duration)
        if scheduler is not None:
            scheduler.observe(ev.cid, float(cost.work_units(e.work)),
                              ev.duration)
        if ftrace is not None and ftrace.dropped(ev.cid, e.dispatch_ix):
            # mid-round dropout: the client trained, but its update is
            # lost in flight.  Its dispatch was already fully accounted
            # (trace cursor, busy time, capability EWMA), so surviving
            # clients' capability/jitter draws are byte-identical with
            # the fault-free run — only the merge never sees this one.
            rec_dropped += 1
            dropped_total += 1
            obs.metrics.counter("faults.dropped_updates").inc()
            params_by_version[e.v0][1] -= 1     # ref will never merge
            if params_by_version[e.v0][1] <= 0:
                del params_by_version[e.v0]
            continue
        e.staleness = version - e.v0
        staleness_log.append(e.staleness)
        obs.metrics.histogram("staleness", exact=True).observe(e.staleness)
        buffer.append(e)
        occupancy_log.append(len(buffer))
        obs.metrics.histogram("buffer_occupancy",
                              exact=True).observe(len(buffer))
        if len(buffer) >= buffer_k:
            merge_buffer(now, partial=False)

    # final drain: an under-filled buffer at termination holds real
    # client work — merge it (counted as a partial flush) instead of
    # dropping the tail, mirroring Aggregator.flush in the event runtime
    if buffer and applied < cfg.max_updates:
        merge_buffer(now, partial=True)
    if fill_span is not None:
        obs.span_end(fill_span)
    if round_span is not None:
        obs.span_end(round_span)    # the trailing cutoff window, if open

    makespan = now
    # credit clients still mid-training at termination for busy time
    # accrued inside [0, makespan] (their COMPLETE never processed)
    for ev in unprocessed + [e for _, _, e in queue._heap]:
        if ev.kind == COMPLETE and ev.cid in pending:
            busy_time[ev.cid] += max(0.0, ev.duration - (ev.time - makespan))
    active = tracei.counts > 0
    shist = (np.bincount(staleness_log) if staleness_log
             else np.zeros(1, np.int64))
    ohist = (np.bincount(occupancy_log) if occupancy_log
             else np.zeros(1, np.int64))
    telemetry = {
        "makespan": float(makespan),
        "client_utilization": float(busy_time.sum()
                                    / max(n * makespan, 1e-12)),
        "active_client_utilization": float(
            busy_time[active].sum()
            / max(active.sum() * makespan, 1e-12)) if active.any() else 0.0,
        "staleness_hist": shist,
        "mean_staleness": (float(np.mean(staleness_log))
                           if staleness_log else 0.0),
        "max_staleness": int(shist.size - 1),
        "buffer_occupancy_hist": ohist,
        "mean_buffer_occupancy": (float(np.mean(occupancy_log))
                                  if occupancy_log else 0.0),
        "n_dispatches": int(tracei.counts.sum()),
        "n_group_dispatches": int(eng.dispatch_count),
        "n_updates_applied": applied,
        "n_merged_clients": merged_total,
        "n_partial_flushes": partial_flushes,
        "n_violations": violations_total,
        "n_dropped_updates": dropped_total,
        "n_corrupted_updates": corrupted_total,
        "wall_time": _time.perf_counter() - wall0,
    }
    if obs.enabled:
        obs.event("telemetry", **{k: (v.tolist() if isinstance(v, np.ndarray)
                                      else v) for k, v in telemetry.items()})
        obs.metrics.gauge("client_utilization").set(
            telemetry["client_utilization"])
        obs.metrics.gauge("active_client_utilization").set(
            telemetry["active_client_utilization"])
        obs.metrics.gauge("makespan_virtual_s").set(telemetry["makespan"])
        obs.metrics.gauge("mean_buffer_occupancy").set(
            telemetry["mean_buffer_occupancy"])
    return {
        "params": params,
        "history": history,
        "deadline": deadline,
        "engine": engine,           # requested
        "engine_mode": mode,        # executed (sharded may fall back)
        "aggregator": rule.name,
        "faults": fault_name,
        "version": version,
        "applied": applied,
        "event_log": event_log,
        "telemetry": telemetry,
        "n_devices": len(jax.devices()),
        "strategy": "fedcore_async_fleet",
    }
