"""Model-diverse fleet workloads behind one ``FleetWorkload`` abstraction.

FedCore's claim is that distributed coreset selection preserves accuracy
across *real* workloads, but until this module the fleet engines only ever
exercised one flat ``(x, y)`` logistic-regression workload.  A
``FleetWorkload`` bundles everything the fleet engines, scenario registry,
and benchmarks need to run a model family end to end:

  * the **model** (init / loss / grad_features / accuracy — the FLModel
    interface of ``repro.models.small``), delegated so a workload can be
    passed anywhere a model is expected (``run_fleet``, ``run_scenario``,
    ``LocalTrainer``);
  * a declared **data schema**: named per-sample array specs
    (shape without the leading sample axis + dtype) that
    ``validate_clients`` checks real client data against — the contract
    the schema-generic engines rely on instead of hardcoded ``x``/``y``
    handling;
  * a **client builder** (``make_clients``) producing the federated
    dataset at any scale, so tests, benchmarks, and demos share one
    construction per workload.

Registry (all sized for CPU-fleet simulation; pass overrides through
``get_workload`` / ``make_clients`` for larger scales):

  * ``mlp``    — LogisticRegression on Synthetic(0.5, 0.5) flat features
                 (the original fleet workload; convex, input-space d̃).
  * ``cnn``    — ``SmallCNN`` on pseudo-MNIST images (``(H, W)`` float32
                 samples; last-layer-gradient d̂ features).
  * ``charlm`` — ``CharLSTM`` on the Shakespeare-style char-LM task
                 (``(S,)`` int32 token sequences with sequence labels).
  * ``xlstm``  — ``CharXLSTM`` (one exponential-gated mLSTM block from
                 ``repro.models.xlstm``) on the same char-LM data.
  * ``translm`` — ``CharTransformer`` (one pre-norm decoder block using
                 ``repro.models.attention``; its tri-state ``use_kernel``
                 routes attention through the Pallas flash kernel) on the
                 same char-LM data.

The engines themselves stay duck-typed — they accept any pytree-of-arrays
client data whose top level is a dict of named fields — so a new workload
is just a ``FleetWorkload`` instance; see README "Adding a new
FleetWorkload".
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import init_attention, multihead_attention
from repro.models.layers import (dense_init, init_mlp, init_rmsnorm, mlp,
                                 rmsnorm)
from repro.models.small import (IGNORE, CharLSTM, LogisticRegression,
                                SmallCNN, _last_layer_grad_feature,
                                _weighted_ce)
from repro.models.xlstm import init_mlstm, mlstm_block

Pytree = Any


def client_num_samples(data: Pytree) -> int:
    """Leading-axis length of a client's data pytree (all leaves share it)."""
    leaves = jax.tree.leaves(data)
    if not leaves:
        raise ValueError("client data pytree has no array leaves")
    return int(leaves[0].shape[0])


def client_sizes(clients_data: Sequence[Pytree]) -> List[int]:
    """Per-client sample counts — the schema-generic replacement for the
    ``len(d["y"])`` idiom scattered through pre-workload callers."""
    return [client_num_samples(d) for d in clients_data]


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """One named field of a workload's per-sample schema."""
    shape: Tuple[int, ...]        # per-sample shape (no leading sample axis)
    dtype: str                    # numpy dtype name, e.g. "float32"


@dataclasses.dataclass(frozen=True)
class FleetWorkload:
    """A model family + data schema + dataset builder, runnable by every
    fleet engine.

    Delegates the FLModel interface to ``model``, so a ``FleetWorkload``
    can be passed wherever a model is expected.  ``make_clients(n_clients,
    seed, **overrides)`` builds the federated dataset; ``schema`` declares
    what that data looks like and ``validate_clients`` enforces it.
    """
    name: str
    model: Any
    schema: Mapping[str, ArraySpec]
    make_clients: Callable[..., List[Dict[str, np.ndarray]]]
    description: str = ""

    # -- FLModel delegation ----------------------------------------------
    def init(self, key):
        return self.model.init(key)

    def loss(self, params, batch):
        return self.model.loss(params, batch)

    def accuracy(self, params, batch):
        return self.model.accuracy(params, batch)

    def grad_features(self, params, batch):
        return self.model.grad_features(params, batch)

    @property
    def feature_space(self) -> str:
        return self.model.feature_space

    # -- schema ----------------------------------------------------------
    def validate_clients(self, clients_data: Sequence[Pytree]) -> None:
        """Check every client against the declared schema: exact top-level
        field names, per-sample shapes, dtypes, and one shared sample
        count across fields.  Raises ``ValueError`` on the first
        mismatch."""
        want = set(self.schema)
        for i, data in enumerate(clients_data):
            if not isinstance(data, Mapping):
                raise ValueError(
                    f"{self.name}: client {i} data must be a mapping of "
                    f"named fields, got {type(data).__name__}")
            got = set(data) - {"weights"}
            if got != want:
                raise ValueError(
                    f"{self.name}: client {i} fields {sorted(got)} != "
                    f"schema fields {sorted(want)}")
            m = client_num_samples(data)
            for kk, spec in self.schema.items():
                v = np.asarray(data[kk])
                if v.shape != (m,) + tuple(spec.shape):
                    raise ValueError(
                        f"{self.name}: client {i} field {kk!r} shape "
                        f"{v.shape} != (m={m},)+{tuple(spec.shape)}")
                if v.dtype != np.dtype(spec.dtype):
                    raise ValueError(
                        f"{self.name}: client {i} field {kk!r} dtype "
                        f"{v.dtype} != {spec.dtype}")


# ---------------------------------------------------------------------------
# xLSTM char-LM: one exponential-gated mLSTM block + tied char head
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CharXLSTM:
    """Char-LM built from one ``repro.models.xlstm`` mLSTM block.

    Same FLModel interface and batch schema as ``CharLSTM`` — tokens in,
    next-token logits out — but the recurrence is the xLSTM exponential-
    gating cell (matrix memory, log-domain stabilizer), giving the fleet
    a second, structurally different sequence workload.
    """
    vocab: int = 64
    d_model: int = 32
    n_heads: int = 2
    feature_space: str = "last_layer_grad"

    def _cfg(self) -> ModelConfig:
        return ModelConfig(arch_id="char_xlstm", family="xlstm",
                           d_model=self.d_model, n_heads=self.n_heads,
                           n_kv_heads=self.n_heads, vocab_size=self.vocab)

    def init(self, key):
        ks = jax.random.split(key, 3)
        return {
            "embed": jax.random.normal(ks[0], (self.vocab, self.d_model))
            * 0.1,
            "mlstm": init_mlstm(ks[1], self._cfg()),
            "w_out": dense_init(ks[2], self.d_model, self.vocab),
            "b_out": jnp.zeros((self.vocab,)),
        }

    def logits(self, params, tokens):
        x = params["embed"][tokens]                     # (B, S, d)
        x, _ = mlstm_block(params["mlstm"], self._cfg(), x)
        return x @ params["w_out"] + params["b_out"]

    def loss(self, params, batch):
        logits = self.logits(params, batch["x"])
        total, per_example = _weighted_ce(logits, batch["y"],
                                          batch.get("weights"))
        return total, {"loss": total, "per_example_loss": per_example}

    def accuracy(self, params, batch):
        logits = self.logits(params, batch["x"])
        valid = batch["y"] != IGNORE
        correct = (jnp.argmax(logits, -1) == batch["y"]) & valid
        return jnp.sum(correct) / jnp.maximum(jnp.sum(valid), 1)

    def grad_features(self, params, batch):
        logits = self.logits(params, batch["x"])
        return _last_layer_grad_feature(logits, batch["y"], params["w_out"])


# ---------------------------------------------------------------------------
# transformer char-LM: one pre-norm decoder block over the flash kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CharTransformer:
    """Char-LM built from one pre-norm decoder block of
    ``repro.models.attention``.

    Same FLModel interface and batch schema as ``CharLSTM``/``CharXLSTM``
    — tokens in, next-token logits out — but the sequence mixer is causal
    multi-head self-attention with RoPE.  ``use_kernel`` is the repo's
    tri-state Pallas switch (PR 4 semantics): ``True`` routes attention
    through the ``kernels/flash_attention`` Pallas kernel (interpret mode
    off-TPU), ``False`` forces the identical-math jnp path, ``None``
    auto-selects by backend via ``resolve_use_kernel``.  Resolution
    happens at trace time, outside any jit boundary's dynamic values, so
    both settings share the usual compilation-cache behaviour.
    """
    vocab: int = 64
    d_model: int = 32
    n_heads: int = 2
    d_ff: int = 64
    use_kernel: Optional[bool] = None
    feature_space: str = "last_layer_grad"

    def _cfg(self) -> ModelConfig:
        return ModelConfig(arch_id="char_translm", family="transformer",
                           d_model=self.d_model, n_heads=self.n_heads,
                           n_kv_heads=self.n_heads, vocab_size=self.vocab)

    def _impl(self) -> str:
        from repro.kernels.ops import resolve_use_kernel
        return "pallas" if resolve_use_kernel(self.use_kernel) else "naive"

    def init(self, key):
        cfg = self._cfg()
        ks = jax.random.split(key, 4)
        return {
            "embed": jax.random.normal(ks[0], (self.vocab, self.d_model))
            * 0.1,
            "norm_attn": init_rmsnorm(self.d_model),
            "attn": init_attention(ks[1], cfg),
            "norm_mlp": init_rmsnorm(self.d_model),
            "mlp": init_mlp(ks[2], cfg, d_ff=self.d_ff),
            "norm_out": init_rmsnorm(self.d_model),
            "w_out": dense_init(ks[3], self.d_model, self.vocab),
            "b_out": jnp.zeros((self.vocab,)),
        }

    def logits(self, params, tokens):
        cfg = self._cfg()
        x = params["embed"][tokens]                     # (B, S, d)
        x = x + multihead_attention(
            params["attn"], cfg, rmsnorm(params["norm_attn"], x),
            causal=True, impl=self._impl())
        x = x + mlp(params["mlp"], rmsnorm(params["norm_mlp"], x),
                    act=cfg.act)
        x = rmsnorm(params["norm_out"], x)
        return x @ params["w_out"] + params["b_out"]

    def loss(self, params, batch):
        logits = self.logits(params, batch["x"])
        total, per_example = _weighted_ce(logits, batch["y"],
                                          batch.get("weights"))
        return total, {"loss": total, "per_example_loss": per_example}

    def accuracy(self, params, batch):
        logits = self.logits(params, batch["x"])
        valid = batch["y"] != IGNORE
        correct = (jnp.argmax(logits, -1) == batch["y"]) & valid
        return jnp.sum(correct) / jnp.maximum(jnp.sum(valid), 1)

    def grad_features(self, params, batch):
        logits = self.logits(params, batch["x"])
        return _last_layer_grad_feature(logits, batch["y"], params["w_out"])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _mlp_workload() -> FleetWorkload:
    from repro.data.synthetic import synthetic_dataset
    n_features, n_classes = 60, 10

    def make_clients(n_clients: int = 64, seed: int = 0,
                     mean_samples: float = 48.0, std_samples: float = 32.0
                     ) -> List[Dict[str, np.ndarray]]:
        return synthetic_dataset(0.5, 0.5, n_clients=n_clients,
                                 n_features=n_features, n_classes=n_classes,
                                 mean_samples=mean_samples,
                                 std_samples=std_samples, seed=seed)

    return FleetWorkload(
        name="mlp", model=LogisticRegression(n_features, n_classes),
        schema={"x": ArraySpec((n_features,), "float32"),
                "y": ArraySpec((), "int32")},
        make_clients=make_clients,
        description="LogisticRegression on Synthetic(0.5, 0.5) flat "
                    "features (convex; input-space distances)")


def _cnn_workload() -> FleetWorkload:
    from repro.data.mnist_like import mnist_like_dataset
    # 14x14 pseudo-MNIST: same task family as the paper's MNIST benchmark
    # at a quarter of the pixels, so CPU fleet rounds stay fast
    size, channels = 14, (8, 16)

    def make_clients(n_clients: int = 64, seed: int = 0,
                     mean_samples: float = 40.0, std_samples: float = 24.0
                     ) -> List[Dict[str, np.ndarray]]:
        return mnist_like_dataset(n_clients=n_clients,
                                  mean_samples=mean_samples,
                                  std_samples=std_samples,
                                  size=size, seed=seed)

    return FleetWorkload(
        name="cnn", model=SmallCNN(image_size=size, channels=channels),
        schema={"x": ArraySpec((size, size), "float32"),
                "y": ArraySpec((), "int32")},
        make_clients=make_clients,
        description="SmallCNN on pseudo-MNIST images "
                    "(last-layer-gradient features)")


_CHARLM_SEQ_LEN = 16


def _charlm_clients(n_clients: int = 64, seed: int = 0,
                    mean_samples: float = 40.0, std_samples: float = 24.0
                    ) -> List[Dict[str, np.ndarray]]:
    from repro.data.charlm import shakespeare_like_dataset
    return shakespeare_like_dataset(n_clients=n_clients,
                                    mean_samples=mean_samples,
                                    std_samples=std_samples,
                                    seq_len=_CHARLM_SEQ_LEN, seed=seed)


def _charlm_workload() -> FleetWorkload:
    from repro.data.charlm import VOCAB
    return FleetWorkload(
        name="charlm",
        model=CharLSTM(vocab=VOCAB, d_embed=8, d_hidden=32, n_layers=1),
        schema={"x": ArraySpec((_CHARLM_SEQ_LEN,), "int32"),
                "y": ArraySpec((_CHARLM_SEQ_LEN,), "int32")},
        make_clients=_charlm_clients,
        description="CharLSTM next-character prediction on the "
                    "Shakespeare-style char-LM task")


def _xlstm_workload() -> FleetWorkload:
    from repro.data.charlm import VOCAB
    return FleetWorkload(
        name="xlstm",
        model=CharXLSTM(vocab=VOCAB, d_model=32, n_heads=2),
        schema={"x": ArraySpec((_CHARLM_SEQ_LEN,), "int32"),
                "y": ArraySpec((_CHARLM_SEQ_LEN,), "int32")},
        make_clients=_charlm_clients,
        description="one-block exponential-gated mLSTM char-LM on the "
                    "same sequence data as charlm")


def _translm_workload() -> FleetWorkload:
    from repro.data.charlm import VOCAB
    return FleetWorkload(
        name="translm",
        model=CharTransformer(vocab=VOCAB, d_model=32, n_heads=2),
        schema={"x": ArraySpec((_CHARLM_SEQ_LEN,), "int32"),
                "y": ArraySpec((_CHARLM_SEQ_LEN,), "int32")},
        make_clients=_charlm_clients,
        description="one-block pre-norm decoder transformer char-LM "
                    "(flash-attention kernel capable) on the same "
                    "sequence data as charlm")


WORKLOADS: Dict[str, Callable[[], FleetWorkload]] = {
    "mlp": _mlp_workload,
    "cnn": _cnn_workload,
    "charlm": _charlm_workload,
    "xlstm": _xlstm_workload,
    "translm": _translm_workload,
}


def get_workload(name: str) -> FleetWorkload:
    """Materialize a registered workload by name."""
    try:
        return WORKLOADS[name]()
    except KeyError:
        raise ValueError(f"unknown fleet workload {name!r} "
                         f"(expected one of {sorted(WORKLOADS)})") from None
