from repro.fed.fleet.async_engine import (  # noqa: F401
    ASYNC_MERGES,
    AsyncFleetConfig,
    AsyncMergeRule,
    DelayedGradientMerge,
    FedAsyncMerge,
    FedBuffMerge,
    RobustMerge,
    as_merge_rule,
    run_async_fleet,
)
from repro.fed.fleet.faults import (  # noqa: F401
    FAULT_PROFILES,
    FaultProfile,
    FaultTrace,
    corrupt_stacked,
    corrupt_update,
    dirichlet_label_skew,
    get_fault_profile,
)
from repro.fed.fleet.batched import (  # noqa: F401
    CohortGroup,
    FleetConfig,
    FleetEngine,
    FleetRoundStats,
    make_cohort_groups,
    run_fleet,
    run_fleet_round,
    weighted_param_sum,
)
from repro.fed.fleet.scenarios import (  # noqa: F401
    SCENARIOS,
    Scenario,
    build_scenario,
    run_scenario,
)
from repro.fed.fleet.scheduler import (  # noqa: F401
    AdaptiveParticipation,
    ParticipationConfig,
)
from repro.fed.fleet.sharded import (  # noqa: F401
    ShardedFleetEngine,
    client_mesh,
)
from repro.fed.fleet.workloads import (  # noqa: F401
    WORKLOADS,
    ArraySpec,
    CharXLSTM,
    FleetWorkload,
    client_num_samples,
    client_sizes,
    get_workload,
)
