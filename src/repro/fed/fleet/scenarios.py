"""Named heterogeneity scenarios and the unified sweep entry point.

"Learning from Straggler Clients" (Hard et al., 2024) shows that which
aggregation/participation rule wins depends on the *shape* of the fleet's
arrival process, not just its mean — so the repo needs reusable, named
regimes rather than one hard-coded capability sampler.  Each ``Scenario``
pins (a) the static capability distribution and (b) the time-varying
``TraceConfig`` (slowdown episodes + jitter) that together define a
fleet's heterogeneity.  ``run_scenario`` drives the same scenario through
any of the three runtimes — the synchronous server, the async event
engine, or the batched fleet driver — so regimes are directly comparable
across execution models.

Registry (all capability samplers are mean-≈1 so deadlines stay
comparable across scenarios):

  * ``uniform``          — the paper's N(1, 0.25) population, mild jitter.
  * ``pareto``           — Lomax(α=2) capabilities: a heavy tail of nearly-
                           dead devices and a few very fast ones.
  * ``diurnal``          — long correlated slow periods (devices charging /
                           busy for many consecutive dispatches).
  * ``flash_crowd``      — frequent short, severe contention spikes.
  * ``device_classes``   — a 3-class hardware mixture (low-end 0.3×,
                           mid 1×, flagship 3×) with per-device spread.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fed.fleet.workloads import FleetWorkload, client_sizes, get_workload
from repro.fed.simulator import ClientSpec, TraceConfig
from repro.obs import get_recorder


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    capability_kind: str                  # normal | pareto | classes
    cap_params: Tuple[float, ...] = ()
    jitter_std: float = 0.1
    slowdown_prob: float = 0.03
    slowdown_factor: float = 3.0
    slowdown_mean_len: float = 3.0

    def sample_capabilities(self, n: int, rng: np.random.Generator,
                            floor: float = 0.05) -> np.ndarray:
        if self.capability_kind == "normal":
            mean, var = self.cap_params
            c = rng.normal(mean, np.sqrt(var), n)
        elif self.capability_kind == "pareto":
            (alpha,) = self.cap_params
            # Lomax(α): mean 1/(α−1); α=2 ⇒ mean 1 with a heavy slow tail
            c = rng.pareto(alpha, n)
        elif self.capability_kind == "classes":
            speeds = np.array(self.cap_params[0::2])
            probs = np.array(self.cap_params[1::2])
            cls = rng.choice(len(speeds), size=n, p=probs / probs.sum())
            # ±20% lognormal per-device spread within a hardware class
            c = speeds[cls] * rng.lognormal(-0.02, 0.2, n)
        else:
            raise ValueError(f"unknown capability_kind "
                             f"{self.capability_kind!r}")
        return np.maximum(c, floor)

    def trace_config(self, seed: int) -> TraceConfig:
        return TraceConfig(jitter_std=self.jitter_std,
                           slowdown_prob=self.slowdown_prob,
                           slowdown_factor=self.slowdown_factor,
                           slowdown_mean_len=self.slowdown_mean_len,
                           seed=seed)


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in [
    Scenario("uniform",
             "paper-default N(1, 0.25) capabilities, mild jitter",
             "normal", (1.0, 0.25),
             jitter_std=0.1, slowdown_prob=0.02),
    Scenario("pareto",
             "Lomax(2) heavy-tailed capabilities: many slow, few fast",
             "pareto", (2.0,),
             jitter_std=0.15, slowdown_prob=0.05),
    Scenario("diurnal",
             "long correlated slow episodes (charging/busy devices)",
             "normal", (1.0, 0.1),
             jitter_std=0.1, slowdown_prob=0.04, slowdown_factor=2.5,
             slowdown_mean_len=12.0),
    Scenario("flash_crowd",
             "frequent short severe contention spikes",
             "normal", (1.0, 0.15),
             jitter_std=0.25, slowdown_prob=0.2, slowdown_factor=5.0,
             slowdown_mean_len=2.0),
    Scenario("device_classes",
             "3-class hardware mixture: 20% 0.3x, 60% 1x, 20% 3x",
             "classes", (0.3, 0.2, 1.0, 0.6, 3.0, 0.2),
             jitter_std=0.12, slowdown_prob=0.03),
]}


def build_scenario(name: str, sizes: Sequence[int], seed: int = 0
                   ) -> Tuple[List[ClientSpec], TraceConfig]:
    """Materialize a named scenario for clients of the given data sizes."""
    scenario = SCENARIOS[name]
    # zlib.crc32, not hash(): str hashing is salted per process and would
    # break cross-run scenario determinism
    name_key = zlib.crc32(name.encode())
    rng = np.random.default_rng(np.random.SeedSequence((seed, name_key)))
    caps = scenario.sample_capabilities(len(sizes), rng)
    specs = [ClientSpec(cid=i, m=int(m), c=float(c))
             for i, (m, c) in enumerate(zip(sizes, caps))]
    return specs, scenario.trace_config(seed)


def run_scenario(name: str, runtime: str, model=None, clients_data=None,
                 test_data: Optional[Dict] = None, *, seed: int = 0,
                 rounds: int = 5, clients_per_round: int = 8,
                 epochs: int = 3, batch_size: int = 8, lr: float = 0.05,
                 straggler_pct: float = 30.0,
                 max_updates: Optional[int] = None, concurrency: int = 8,
                 scheduler=None, aggregator=None, faults=None,
                 fleet_engine: str = "batched",
                 use_kernel: Optional[bool] = None,
                 workload=None, n_clients: int = 24,
                 cost=None, verbose: bool = False) -> Dict[str, Any]:
    """Drive one named scenario through one runtime.

    ``runtime`` ∈ {"sync", "async", "fleet", "async_fleet"}: the
    synchronous round server (``run_federated`` with the FedCore
    strategy), the async event engine (``run_federated_async``), the
    batched fleet driver (``run_fleet``), or the event-driven fleet
    engine (``run_async_fleet`` — buffered completions micro-batched
    into fused cohort-group programs).  All of them consume the same
    specs + capability trace from the registry, so a scenario means the
    same fleet everywhere.  ``fleet_engine`` selects the fleet execution
    model ("batched" | "loop" | "sharded" — the mesh-sharded engine,
    falling back to batched on one device) for both fleet runtimes.
    For ``async_fleet``, ``max_updates`` counts buffer flushes
    (defaulting to ``rounds``) and ``clients_per_round`` doubles as the
    buffer size K, so a sync round and an async flush merge comparable
    amounts of client work.
    ``use_kernel`` is the tri-state Pallas switch for the coreset
    selection fast path (None = auto by backend), threaded into whichever
    runtime's config does the selecting.

    ``workload`` is the model-diversity axis: a registry name
    (``"mlp"``/``"cnn"``/``"charlm"``/``"xlstm"``) or a ``FleetWorkload``
    instance.  When given, it supplies the model (``model`` may then be
    omitted), and — if ``clients_data`` is also omitted — builds an
    ``n_clients``-client federated dataset from its own generator,
    validated against the workload's declared schema.  The result dict
    gains ``scenario``, ``runtime``, and (with a workload) ``workload``
    keys.

    ``cost`` (a ``repro.fed.cost.WorkloadCostModel``, a per-sample
    scalar, or None for the legacy samples-cost-1.0 unit) prices one
    sample-visit of the workload and is threaded into whichever
    runtime's config derives deadlines, budgets, and durations — see
    ``repro.fed.cost.workload_cost_model`` for measuring it.

    ``faults`` is the orthogonal fault axis (a
    ``repro.fed.fleet.faults.FaultProfile``, a registry name like
    ``"byzantine_signflip"``, or None): its label-skew component
    repartitions ``clients_data`` (sizes preserved, so specs and
    capability draws are unchanged) before the run, and the remaining
    axes — dropout, churn, update corruption — are threaded into
    whichever runtime executes.  ``aggregator`` likewise accepts a
    robust-method name (``repro.fed.aggregators.ROBUST_METHODS``) on
    every runtime, so a fault profile x aggregator grid runs the same
    scenario end to end.
    """
    # late imports: repro.fed.{server,events,strategies} import nothing from
    # fleet, keeping this the only direction of coupling
    from repro.core.coreset import FedCoreConfig
    from repro.fed.aggregators import (AGGREGATORS, ROBUST_METHODS,
                                       RobustAggregate, SyncWeightedMean)
    from repro.fed.events import AsyncFLConfig, run_federated_async
    from repro.fed.fleet.async_engine import (AsyncFleetConfig,
                                              run_async_fleet)
    from repro.fed.fleet.batched import FleetConfig, run_fleet
    from repro.fed.fleet.faults import (dirichlet_label_skew,
                                        get_fault_profile)
    from repro.fed.server import FLConfig, run_federated
    from repro.fed.strategies import FedCore, LocalTrainer

    wl: Optional[FleetWorkload] = None
    if workload is not None:
        wl = (workload if isinstance(workload, FleetWorkload)
              else get_workload(workload))
        model = wl if model is None else model
        if clients_data is None:
            clients_data = wl.make_clients(n_clients=n_clients, seed=seed)
        wl.validate_clients(clients_data)
    if model is None or clients_data is None:
        raise ValueError("run_scenario needs model + clients_data, or a "
                         "workload to build them from")
    profile = get_fault_profile(faults)
    fault_name = profile.name if profile is not None else "none"
    if profile is not None and profile.label_skew_alpha is not None:
        # label skew repartitions the data but preserves per-client
        # sizes, so specs, budgets, and capability draws are untouched
        clients_data = dirichlet_label_skew(
            clients_data, profile.label_skew_alpha, seed=seed)
    sizes = client_sizes(clients_data)
    specs, trace = build_scenario(name, sizes, seed)
    core_cfg = FedCoreConfig(use_kernel=use_kernel)
    # stamped before the runtime's own run record, so a JSONL log opens
    # with the scenario context the report CLI keys on
    get_recorder().event("scenario", scenario=name, runtime=runtime,
                         workload=(wl.name if wl is not None else None),
                         faults=fault_name,
                         n_clients=len(specs), seed=seed)

    def _streaming(round_size: int):
        """Coerce ``aggregator`` into a streaming Aggregator instance for
        the event-driven runtime (robust names buffer one round's worth
        of updates before combining, matching the sync semantics)."""
        if aggregator is None or not isinstance(aggregator, str):
            return aggregator
        if aggregator in ROBUST_METHODS:
            return RobustAggregate(aggregator, round_size=round_size)
        if aggregator == "sync_mean":
            return SyncWeightedMean(round_size=round_size)
        return AGGREGATORS[aggregator]()

    if runtime == "sync":
        cfg = FLConfig(rounds=rounds, clients_per_round=clients_per_round,
                       epochs=epochs, batch_size=batch_size, lr=lr,
                       straggler_pct=straggler_pct, seed=seed, trace=trace,
                       cost=cost)
        strat = FedCore(LocalTrainer(model, lr, batch_size, cost=cost),
                        core_cfg)
        sync_agg = aggregator if isinstance(aggregator, str) else \
            "weighted_mean"
        out = run_federated(model, clients_data, specs, strat, cfg,
                            test_data=test_data, scheduler=scheduler,
                            aggregator=sync_agg, faults=profile,
                            verbose=verbose)
    elif runtime == "async":
        cfg = AsyncFLConfig(
            max_updates=max_updates or rounds * clients_per_round,
            concurrency=concurrency, epochs=epochs, batch_size=batch_size,
            lr=lr, straggler_pct=straggler_pct,
            record_every=clients_per_round, seed=seed, trace=trace,
            cost=cost)
        strat = FedCore(LocalTrainer(model, lr, batch_size, cost=cost),
                        core_cfg)
        out = run_federated_async(model, clients_data, specs, strat, cfg,
                                  aggregator=_streaming(clients_per_round),
                                  test_data=test_data, scheduler=scheduler,
                                  faults=profile, verbose=verbose)
    elif runtime == "fleet":
        fleet_agg = (aggregator if isinstance(aggregator, str)
                     else "weighted_mean")
        cfg = FleetConfig(epochs=epochs, batch_size=batch_size, lr=lr,
                          seed=seed, use_kernel=use_kernel, cost=cost,
                          aggregator=fleet_agg)
        out = run_fleet(model, clients_data, specs, cfg, rounds=rounds,
                        scheduler=scheduler, trace=trace,
                        straggler_pct=straggler_pct, test_data=test_data,
                        engine=fleet_engine, faults=profile,
                        verbose=verbose)
    elif runtime == "async_fleet":
        cfg = AsyncFleetConfig(
            max_updates=max_updates or rounds,
            buffer_k=clients_per_round,
            concurrency=max(concurrency, clients_per_round),
            epochs=epochs, batch_size=batch_size, lr=lr,
            straggler_pct=straggler_pct, seed=seed,
            use_kernel=use_kernel, trace=trace, cost=cost)
        out = run_async_fleet(model, clients_data, specs, cfg,
                              aggregator=aggregator, scheduler=scheduler,
                              test_data=test_data, engine=fleet_engine,
                              faults=profile, verbose=verbose)
    else:
        raise ValueError(f"unknown runtime {runtime!r}")
    out["scenario"] = name
    out["runtime"] = runtime
    out.setdefault("faults", fault_name)
    if wl is not None:
        out["workload"] = wl.name
    return out
