"""Seeded, deterministic fault injection for fleet-scale FL.

A million-user fleet fails in more ways than slowness (the only axis the
scenario registry models): clients drop mid-round, join and leave
between rounds, send noisy or adversarial updates, and hold
label-skewed non-IID data.  "Learning from Straggler Clients" (Hard et
al., 2024) and the FL survey (Collins & Wang) both name partial
participation, update corruption, and non-IID skew as the failure axes
a production FL system must survive.  This module makes each of them an
orthogonal, composable axis that any capability scenario can be crossed
with:

  * **mid-round dropout** — the client completes its dispatch (the
    work happens, the capability-trace entry is consumed, the scheduler
    observes the duration) but the *update* is lost with probability p
    before it reaches the server;
  * **join/leave churn** — per-round Bernoulli arrival/departure over
    the whole client universe (a two-state Markov chain per client), so
    the active set is a moving subset of a larger population;
  * **update corruption** — a fixed Byzantine subset of clients sends
    Gaussian-noised, sign-flipped, or scaled/boosted models every time
    it participates (the classic attack models Krum / trimmed-mean
    aggregation defends against);
  * **label-skew partitioning** — ``dirichlet_label_skew`` resamples a
    federated dataset so each client's label distribution follows a
    Dirichlet(α) draw, the standard non-IID benchmark construction.
    This axis transforms the *dataset* before a run (``run_scenario``
    applies it); the runtime axes above act per dispatch/round.

Every axis is a pure function of ``(seed, profile, cid, index)``:
dropout draws come from per-client streams indexed by the client's own
dispatch ordinal (the ``DispatchTraceIndexer`` contract), churn masks
from per-round streams, Byzantine membership from one draw at
construction — so fault-injected runs replay byte-identically, compose
with checkpoint/resume, and never perturb the capability-trace draws of
the surviving clients.

Fault events surface through ``repro.obs``: counters
``faults.dropped_updates`` / ``faults.corrupted_updates`` /
``faults.churn_joins`` / ``faults.churn_leaves`` and per-round gauges
``faults.n_present`` / ``faults.participation_frac``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# stream tags: disjoint SeedSequence lanes per fault axis, so axes are
# independent and adding one never shifts another's draws
_TAG_BYZANTINE = 0xB1
_TAG_DROPOUT = 0xD0
_TAG_CHURN = 0xC4
_TAG_NOISE = 0x6E
_TAG_SKEW = 0x5C

CORRUPT_MODES = ("none", "gaussian", "sign_flip", "scaled")


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """One named combination of fault axes (all default off)."""
    name: str = "none"
    description: str = ""
    # P(update lost | dispatch completed) — per (client, dispatch)
    dropout_prob: float = 0.0
    # per-round churn Markov chain over the client universe
    leave_prob: float = 0.0       # P(present -> absent) per round
    join_prob: float = 0.0        # P(absent -> present) per round
    initial_present_frac: float = 1.0   # universe fraction present at t=0
    # Byzantine update corruption (fixed client subset)
    corrupt_mode: str = "none"    # none | gaussian | sign_flip | scaled
    corrupt_frac: float = 0.0     # fraction of Byzantine clients
    noise_std: float = 0.5        # gaussian: additive N(0, std^2) per weight
    scale_factor: float = 10.0    # scaled: delta boosted by this factor
    # non-IID label skew (data-prep axis; None = leave the data as built)
    label_skew_alpha: Optional[float] = None
    seed: int = 0                 # profile salt, mixed with the run seed

    def __post_init__(self):
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r} "
                             f"(expected one of {CORRUPT_MODES})")

    @property
    def has_dropout(self) -> bool:
        return self.dropout_prob > 0.0

    @property
    def has_churn(self) -> bool:
        return (self.leave_prob > 0.0 or self.join_prob > 0.0
                or self.initial_present_frac < 1.0)

    @property
    def has_corruption(self) -> bool:
        return self.corrupt_mode != "none" and self.corrupt_frac > 0.0

    def any_faults(self) -> bool:
        """True when any *runtime* axis is active (label skew is a
        data-prep axis and does not need a FaultTrace)."""
        return self.has_dropout or self.has_churn or self.has_corruption


FAULT_PROFILES: Dict[str, FaultProfile] = {p.name: p for p in [
    FaultProfile("none", "no faults"),
    FaultProfile("dropout",
                 "20% of completed updates are lost mid-round",
                 dropout_prob=0.2),
    FaultProfile("churn",
                 "70% of the universe present at t=0; 15%/25% per-round "
                 "leave/join rates",
                 leave_prob=0.15, join_prob=0.25, initial_present_frac=0.7),
    FaultProfile("byzantine_signflip",
                 "20% of clients send sign-flipped updates",
                 corrupt_mode="sign_flip", corrupt_frac=0.2),
    FaultProfile("byzantine_noise",
                 "20% of clients add N(0, 0.5^2) noise to every weight",
                 corrupt_mode="gaussian", corrupt_frac=0.2, noise_std=0.5),
    FaultProfile("byzantine_boost",
                 "10% of clients send 10x-boosted update deltas",
                 corrupt_mode="scaled", corrupt_frac=0.1, scale_factor=10.0),
    FaultProfile("label_skew",
                 "Dirichlet(0.3) label-skew non-IID partitioning",
                 label_skew_alpha=0.3),
    FaultProfile("hostile",
                 "everything at once: dropout + churn + 20% sign-flip "
                 "Byzantine + Dirichlet(0.5) label skew",
                 dropout_prob=0.1, leave_prob=0.1, join_prob=0.2,
                 initial_present_frac=0.8, corrupt_mode="sign_flip",
                 corrupt_frac=0.2, label_skew_alpha=0.5),
]}


def get_fault_profile(profile) -> Optional[FaultProfile]:
    """Coerce None | registry name | FaultProfile into a profile."""
    if profile is None:
        return None
    if isinstance(profile, FaultProfile):
        return profile
    if isinstance(profile, str):
        try:
            return FAULT_PROFILES[profile]
        except KeyError:
            raise ValueError(
                f"unknown fault profile {profile!r} "
                f"(expected one of {sorted(FAULT_PROFILES)})") from None
    raise TypeError(f"cannot derive a fault profile from "
                    f"{type(profile).__name__}")


class FaultTrace:
    """Deterministic per-run realization of a ``FaultProfile``.

    Dropout is drawn from per-client streams indexed by the client's own
    dispatch ordinal (the same per-(client, dispatch) contract as
    ``CapabilityTrace``), churn from per-round streams, and Byzantine
    membership once at construction — so every query is a pure function
    of ``(run seed, profile, cid, index)``.  Lazy caches only memoize
    those pure functions: a ``FaultTrace`` rebuilt after a checkpoint
    restore regenerates identical draws.
    """

    def __init__(self, profile: FaultProfile, n_clients: int, seed: int = 0):
        self.profile = profile
        self.n = int(n_clients)
        self._seed = (int(seed), int(profile.seed))
        self.byzantine = np.zeros(self.n, bool)
        if profile.has_corruption:
            n_bad = min(self.n, int(round(profile.corrupt_frac * self.n)))
            if n_bad > 0:
                rng = np.random.default_rng(np.random.SeedSequence(
                    (*self._seed, _TAG_BYZANTINE)))
                self.byzantine[rng.choice(self.n, size=n_bad,
                                          replace=False)] = True
        self._drop_draws: Dict[int, List[float]] = {}
        self._present: List[np.ndarray] = []

    # -- dropout ----------------------------------------------------------

    def dropped(self, cid: int, dispatch_index: int) -> bool:
        """Was this (client, dispatch)'s update lost in transit?"""
        if not self.profile.has_dropout:
            return False
        draws = self._drop_draws.setdefault(int(cid), [])
        # one fresh stream per ordinal: extension order can never
        # matter, only (cid, dispatch_index) does
        while len(draws) <= dispatch_index:
            rng = np.random.default_rng(np.random.SeedSequence(
                (*self._seed, _TAG_DROPOUT, int(cid), len(draws))))
            draws.append(float(rng.random()))
        return draws[dispatch_index] < self.profile.dropout_prob

    # -- churn ------------------------------------------------------------

    def present_mask(self, t: int) -> np.ndarray:
        """(n,) bool universe-presence mask for round/flush ``t``."""
        if not self.profile.has_churn:
            return np.ones(self.n, bool)
        while len(self._present) <= t:
            r = len(self._present)
            rng = np.random.default_rng(np.random.SeedSequence(
                (*self._seed, _TAG_CHURN, r)))
            if r == 0:
                frac = self.profile.initial_present_frac
                mask = (np.ones(self.n, bool) if frac >= 1.0
                        else rng.random(self.n) < frac)
            else:
                prev = self._present[-1]
                u = rng.random(self.n)
                mask = np.where(prev, u >= self.profile.leave_prob,
                                u < self.profile.join_prob)
            self._present.append(mask)
        return self._present[t]

    def churn_step(self, t: int) -> Tuple[np.ndarray, int, int]:
        """Presence mask at ``t`` plus (joins, leaves) vs ``t - 1``."""
        mask = self.present_mask(t)
        if t <= 0 or not self.profile.has_churn:
            return mask, 0, 0
        prev = self.present_mask(t - 1)
        joins = int((mask & ~prev).sum())
        leaves = int((prev & ~mask).sum())
        return mask, joins, leaves

    # -- corruption -------------------------------------------------------

    def corrupt_factor(self) -> float:
        """Delta multiplier for a Byzantine client: corrupted params are
        ``base + factor * (params - base)`` (gaussian keeps factor 1 and
        adds noise instead)."""
        mode = self.profile.corrupt_mode
        if mode == "sign_flip":
            return -1.0
        if mode == "scaled":
            return float(self.profile.scale_factor)
        return 1.0

    def _noise_like(self, leaf_shapes, leaf_dtypes, cid: int,
                    dispatch_index: int) -> List[np.ndarray]:
        """Per-(client, dispatch) Gaussian noise, one array per leaf in
        flatten order — deterministic regardless of engine."""
        rng = np.random.default_rng(np.random.SeedSequence(
            (*self._seed, _TAG_NOISE, int(cid), int(dispatch_index))))
        std = self.profile.noise_std
        return [rng.normal(0.0, std, size=shape).astype(dt)
                for shape, dt in zip(leaf_shapes, leaf_dtypes)]


def corrupt_update(params: Pytree, base: Pytree, cid: int,
                   dispatch_index: int, trace: FaultTrace
                   ) -> Tuple[Pytree, bool]:
    """Corrupt one client's update tree if the client is Byzantine.

    Returns ``(params, corrupted?)`` — honest clients' trees are
    returned *unchanged* (same objects, bitwise identical), preserving
    every no-fault parity contract."""
    if not trace.profile.has_corruption or not trace.byzantine[cid]:
        return params, False
    mode = trace.profile.corrupt_mode
    if mode == "gaussian":
        leaves, treedef = jax.tree.flatten(params)
        noise = trace._noise_like([np.shape(x) for x in leaves],
                                  [np.asarray(x).dtype for x in leaves],
                                  cid, dispatch_index)
        return treedef.unflatten([x + n for x, n in zip(leaves, noise)]), True
    f = trace.corrupt_factor()
    out = jax.tree.map(lambda b, p: b + f * (p - b), base, params)
    return out, True


def corrupt_stacked(stacked: Pytree, base: Pytree, cids: np.ndarray,
                    dispatch_ix: np.ndarray, trace: FaultTrace
                    ) -> Tuple[Pytree, int]:
    """Corrupt the Byzantine lanes of a (C, ...) stacked update pytree.

    Only corrupted lanes are rewritten (indexed ``.at[idx].set``), so
    honest lanes stay bitwise identical to the engine's output.  Returns
    ``(stacked, n_corrupted)``."""
    if not trace.profile.has_corruption:
        return stacked, 0
    mask = trace.byzantine[np.asarray(cids, np.int64)]
    idx = np.nonzero(mask)[0]
    if idx.size == 0:
        return stacked, 0
    mode = trace.profile.corrupt_mode
    sub = jax.tree.map(lambda x: x[idx], stacked)
    if mode == "gaussian":
        leaves, treedef = jax.tree.flatten(sub)
        lanes = []
        for lane, (cid, k) in enumerate(zip(np.asarray(cids)[idx],
                                            np.asarray(dispatch_ix)[idx])):
            noise = trace._noise_like(
                [x.shape[1:] for x in leaves],
                [np.asarray(x).dtype for x in leaves], int(cid), int(k))
            lanes.append(noise)
        noise_stack = [np.stack([lanes[i][j] for i in range(len(lanes))])
                       for j in range(len(leaves))]
        sub = treedef.unflatten([x + jnp.asarray(n)
                                 for x, n in zip(leaves, noise_stack)])
    else:
        f = trace.corrupt_factor()
        sub = jax.tree.map(lambda b, x: b[None] + f * (x - b[None]),
                           base, sub)
    out = jax.tree.map(lambda x, s: jnp.asarray(x).at[jnp.asarray(idx)]
                       .set(s), stacked, sub)
    return out, int(idx.size)


# ---------------------------------------------------------------------------
# label-skew non-IID partitioning (data-prep axis)
# ---------------------------------------------------------------------------

def _label_keys(labels: np.ndarray) -> np.ndarray:
    """Scalar per-sample class key: the label itself, or the first token
    of a sequence label (char-LM / transformer-LM workloads)."""
    labels = np.asarray(labels)
    if labels.ndim == 1:
        return labels
    return labels.reshape(labels.shape[0], -1)[:, 0]


def dirichlet_label_skew(clients_data: Sequence[Pytree], alpha: float,
                         seed: int = 0, label_field: str = "y"
                         ) -> List[Pytree]:
    """Repartition a federated dataset with Dirichlet(α) label skew.

    All samples are pooled, each client draws class proportions
    ``p_i ~ Dir(α · 1_K)`` over the pooled label set, and its ``m_i``
    slots are filled by sampling classes from ``p_i`` and popping
    shuffled per-class index pools (falling back to with-replacement
    resampling when a class pool runs dry).  Client sizes — and hence
    every ``ClientSpec`` / budget / deadline derived from them — are
    preserved; only *which* samples a client holds changes.  Lower α ⇒
    more skew; α → ∞ recovers an IID shuffle."""
    if alpha <= 0.0:
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    clients = list(clients_data)
    if not clients:
        return []
    if label_field not in clients[0]:
        raise ValueError(f"label-skew partitioning needs a {label_field!r} "
                         f"field in the client schema")
    pooled = jax.tree.map(lambda *vs: np.concatenate(
        [np.asarray(v) for v in vs]), *clients)
    keys = _label_keys(pooled[label_field])
    classes = np.unique(keys)
    rng = np.random.default_rng(np.random.SeedSequence(
        (int(seed), _TAG_SKEW)))
    pools = {}
    for cls in classes:
        ix = np.nonzero(keys == cls)[0]
        pools[int(cls)] = list(rng.permutation(ix))
    full = {int(cls): np.nonzero(keys == cls)[0] for cls in classes}
    k_cls = len(classes)
    out = []
    for client in clients:
        m = len(np.asarray(next(iter(client.values()))))
        props = rng.dirichlet(np.full(k_cls, float(alpha)))
        draws = rng.choice(k_cls, size=m, p=props)
        take = np.empty(m, np.int64)
        for j, ci in enumerate(draws):
            cls = int(classes[ci])
            pool = pools[cls]
            take[j] = pool.pop() if pool else int(rng.choice(full[cls]))
        out.append(jax.tree.map(lambda v: np.asarray(v)[take], pooled))
    return out
