"""Client-update strategies: FedAvg, FedAvg-DS, FedProx, FedCore (Alg. 1).

A strategy consumes the round-start global params and a client's local data
+ hardware spec, and returns the locally-trained params together with the
*simulated* wall-clock time the update would have taken on that client
(work-units / capability — the paper's timing model, §3.1/§6.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coreset import FedCoreConfig, build_coreset, coreset_batch
from repro.core.gradients import grad_features
from repro.data.batching import epoch_batches
from repro.fed.cost import FORWARD_FRAC, resolve_cost  # noqa: F401 — re-export
from repro.fed.simulator import ClientSpec
from repro.models.training import make_train_step
from repro.obs import get_recorder
from repro.optim.optimizers import sgd


@dataclasses.dataclass
class ClientResult:
    params: Any
    n_samples: int          # aggregation weight basis (mⁱ)
    sim_time: float         # simulated seconds for this round
    used_coreset: bool = False
    coreset_size: int = 0
    epochs_done: float = 0.0
    final_loss: float = 0.0
    # True when even the §4.4 minimal plan (coreset of 1, single partial
    # epoch) cannot meet τ: the client trained anyway but finished late.
    # Footnote 2's honest accounting — the server can see which results
    # broke the deadline instead of a silent budget-clamped-to-1 fiction.
    deadline_violated: bool = False


def _pad_batch(batch: Dict[str, np.ndarray], batch_size: int
               ) -> Dict[str, np.ndarray]:
    """Pad final partial batches to a fixed shape with zero-weight rows."""
    m = len(next(iter(batch.values())))
    if m == batch_size:
        if "weights" not in batch:
            batch = dict(batch, weights=np.ones(m, np.float32))
        return batch
    pad = batch_size - m
    out = {}
    for k, v in batch.items():
        out[k] = np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
    w = (out["weights"].copy() if "weights" in batch
         else np.ones(batch_size, np.float32))
    w[m:] = 0.0
    out["weights"] = w.astype(np.float32)
    return out


class LocalTrainer:
    """Holds the jitted step functions shared by every client/strategy.

    ``cost`` prices one sample-visit for this model's workload (a
    ``repro.fed.cost.WorkloadCostModel``, a per-sample scalar, or None
    for the legacy samples-cost-1.0 unit): every strategy's timing and
    budget arithmetic routes through it, so deadlines mean FLOPs, not
    raw sample counts.
    """

    def __init__(self, model, lr: float, batch_size: int,
                 prox_mu: float = 0.0, cost=None):
        self.model = model
        self.batch_size = batch_size
        self.prox_mu = prox_mu
        self.cost = resolve_cost(cost)
        opt = sgd(lr)
        self.opt = opt
        self._step = make_train_step(model.loss, opt, prox_mu=prox_mu,
                                     donate=False)

    def run_epochs(self, params, data, epochs: int, rng, prox_ref=None,
                   max_steps: Optional[int] = None):
        opt_state = self.opt.init(params)
        steps = 0
        last = 0.0
        stop = False
        for _ in range(int(np.ceil(epochs))):
            for batch in epoch_batches(data, self.batch_size, rng):
                batch = _pad_batch(batch, self.batch_size)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = self._step(params, opt_state,
                                                        batch, prox_ref)
                last = float(metrics["loss"])
                steps += 1
                if max_steps is not None and steps >= max_steps:
                    stop = True
                    break
            if stop:
                break
        return params, steps, last


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class Strategy:
    name = "base"
    deadline_aware = True

    def __init__(self, trainer: LocalTrainer):
        self.trainer = trainer

    def local_update(self, global_params, data, spec: ClientSpec,
                     deadline: float, epochs: int, rng
                     ) -> Optional[ClientResult]:
        raise NotImplementedError


class FedAvg(Strategy):
    """Vanilla FedAvg — deadline-oblivious (the straggler-exposed baseline)."""
    name = "fedavg"
    deadline_aware = False

    def local_update(self, global_params, data, spec, deadline, epochs, rng):
        params, _, loss = self.trainer.run_epochs(global_params, data,
                                                  epochs, rng)
        t = self.trainer.cost.full_round_time(spec.m, spec.c, epochs)
        return ClientResult(params, spec.m, t,
                            epochs_done=epochs, final_loss=loss)


class FedAvgDS(Strategy):
    """FedAvg with Deadline: stragglers are simply dropped from the round."""
    name = "fedavg_ds"

    def local_update(self, global_params, data, spec, deadline, epochs, rng):
        t = self.trainer.cost.full_round_time(spec.m, spec.c, epochs)
        if t > deadline:
            return None  # dropped
        params, _, loss = self.trainer.run_epochs(global_params, data,
                                                  epochs, rng)
        return ClientResult(params, spec.m, t, epochs_done=epochs,
                            final_loss=loss)


class FedProx(Strategy):
    """Proximal term + partial work: stragglers train as many samples as fit
    within τ (Li et al., 2020)."""
    name = "fedprox"

    def local_update(self, global_params, data, spec, deadline, epochs, rng):
        cost = self.trainer.cost
        full_t = cost.full_round_time(spec.m, spec.c, epochs)
        violated = False
        if full_t <= deadline:
            steps = None
            sim_t = full_t
            eff_epochs = float(epochs)
        else:
            samples_budget = cost.available_samples(spec.c, deadline)
            steps = max(1, int(samples_budget // self.trainer.batch_size))
            # honest timing: when even one batch exceeds the budget
            # (cⁱτ < B·κ), the clamped steps=1 plan genuinely overruns τ —
            # report the true duration and flag the violation, exactly as
            # FedCore's footnote-2 accounting does, instead of clamping
            # the reported time to the deadline.
            sim_t = cost.duration(steps * self.trainer.batch_size, spec.c)
            violated = sim_t > deadline * (1.0 + 1e-9)
            eff_epochs = steps * self.trainer.batch_size / spec.m
        params, _, loss = self.trainer.run_epochs(
            global_params, data, epochs, rng, prox_ref=global_params,
            max_steps=steps)
        return ClientResult(params, spec.m, sim_t, epochs_done=eff_epochs,
                            final_loss=loss, deadline_violated=violated)


class FedCore(Strategy):
    """Alg. 1: full-set first epoch -> gradient features -> k-medoids coreset
    -> E−1 coreset epochs (or the §4.4 forward-only fallback)."""
    name = "fedcore"

    def __init__(self, trainer: LocalTrainer, core_cfg: FedCoreConfig
                 | None = None):
        super().__init__(trainer)
        self.core_cfg = core_cfg or FedCoreConfig()

    def local_update(self, global_params, data, spec, deadline, epochs, rng):
        model = self.trainer.model
        cost = self.trainer.cost
        obs = get_recorder()
        if not cost.needs_coreset(spec.m, spec.c, deadline, epochs):
            with obs.span("local_sgd", cid=spec.cid):
                params, _, loss = self.trainer.run_epochs(global_params,
                                                          data, epochs, rng)
            return ClientResult(params, spec.m,
                                cost.full_round_time(spec.m, spec.c, epochs),
                                epochs_done=epochs, final_loss=loss)

        cc = self.core_cfg
        with obs.span("grad_features", cid=spec.cid):
            feats = grad_features(model, global_params, data)
        # Alg. 1 primary schedule (full-set epoch 0 + E−1 coreset epochs at
        # the §4.2 budget) and the §4.4 fallback (forward-only feature
        # pass, coreset-only epochs, epoch shedding for extreme stragglers,
        # footnote-2 honest-overrun accounting) both live in
        # repro.fed.cost — one implementation shared with the fleet
        # schedulers instead of a per-runtime copy.
        plan = cost.primary_plan(spec.m, spec.c, deadline, epochs)
        can_full_first_epoch = plan is not None
        if plan is None:
            plan = cost.fallback_plan(spec.m, spec.c, deadline, epochs)
            if plan.violated and cc.drop_infeasible:
                return None
        budget, eff_epochs = plan.budget, plan.eff_epochs
        work, violated = plan.work, plan.violated

        with obs.span("selection", cid=spec.cid, k=int(budget)):
            coreset = build_coreset(feats, budget, backend=cc.backend,
                                    use_kernel=cc.use_kernel,
                                    max_sweeps=cc.max_sweeps,
                                    projection_dim=cc.projection_dim)
            cdata = coreset_batch(data, coreset, spec.m)

        params = global_params
        loss = 0.0
        if can_full_first_epoch:
            with obs.span("local_sgd", cid=spec.cid):
                params, _, loss = self.trainer.run_epochs(params, data, 1,
                                                          rng)
            with obs.span("coreset_epochs", cid=spec.cid):
                params, _, loss = self.trainer.run_epochs(params, cdata,
                                                          epochs - 1, rng)
        else:
            with obs.span("coreset_epochs", cid=spec.cid):
                params, _, loss = self.trainer.run_epochs(params, cdata,
                                                          eff_epochs, rng)
        return ClientResult(params, spec.m, cost.duration(work, spec.c),
                            used_coreset=True, coreset_size=int(budget),
                            epochs_done=eff_epochs, final_loss=loss,
                            deadline_violated=violated)


STRATEGIES = {
    "fedavg": FedAvg,
    "fedavg_ds": FedAvgDS,
    "fedprox": FedProx,
    "fedcore": FedCore,
}
