"""Event-driven asynchronous FL runtime (virtual clock).

The synchronous loop in ``repro.fed.server`` ends the straggler story at
per-round deadlines: round time is the *max* over participants.  This
module opens the other half of the design space — asynchronous and
semi-synchronous FL (FedAsync, arXiv 1903.03934; FedBuff, arXiv
2106.06639; staleness-discounted delayed gradients, arXiv 2102.06329) —
via a discrete-event simulation:

  * a virtual-clock ``EventQueue`` orders DISPATCH/COMPLETE events by
    ``(time, seq)`` so ties break deterministically;
  * at most ``concurrency`` clients train at once; whenever a slot
    frees, the next idle client is sampled ∝ mⁱ and dispatched with the
    *current* global params;
  * a completion carries the model version it was dispatched from, so
    every update arrives with an exact staleness (in server versions)
    that the pluggable ``Aggregator`` can discount;
  * per-dispatch capability perturbations (``CapabilityTrace``) make the
    arrival process realistic rather than deterministic.

``run_federated_async`` drives any existing ``Strategy`` (FedAvg /
FedProx / FedCore) through this loop, so coreset-based deadline
compliance composes with asynchrony: a FedCore client in a slowdown
episode shrinks its coreset instead of stalling the server.  Everything
is seeded; two runs with the same seed produce byte-identical event logs
and round histories.
"""
from __future__ import annotations

import dataclasses
import heapq
import time as _time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.fed.aggregators import Aggregator, ClientUpdate, FedAsync
from repro.fed.server import RoundRecord, make_eval_fn
from repro.fed.simulator import (CapabilityTrace, ClientSpec,
                                 DispatchTraceIndexer, TraceConfig,
                                 straggler_deadline)
from repro.fed.strategies import Strategy
from repro.obs import active_recorder

DISPATCH = "dispatch"
COMPLETE = "complete"


@dataclasses.dataclass(frozen=True)
class Event:
    time: float     # virtual seconds
    seq: int        # global push order — deterministic tie-break
    kind: str       # DISPATCH | COMPLETE
    cid: int
    version: int    # server model version at dispatch
    duration: float = 0.0   # realized training duration (COMPLETE only)

    def fmt(self) -> str:
        return (f"t={self.time!r} seq={self.seq} {self.kind} "
                f"cid={self.cid} v={self.version} dur={self.duration!r}")


class EventQueue:
    """Min-heap of events keyed by (time, seq)."""

    def __init__(self):
        self._heap: List[Any] = []
        self._seq = 0

    def push(self, time: float, kind: str, cid: int, version: int,
             duration: float = 0.0) -> Event:
        ev = Event(time, self._seq, kind, cid, version, duration)
        self._seq += 1
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)


@dataclasses.dataclass
class AsyncFLConfig:
    max_updates: int = 100        # applied server updates (versions)
    max_virtual_time: Optional[float] = None  # stop once the clock passes this
    # dispatch safety cap so a run where no update can ever be applied
    # (e.g. every client drops) still terminates; None = auto
    max_dispatches: Optional[int] = None
    concurrency: int = 8          # in-flight client cap
    epochs: int = 5               # E
    batch_size: int = 8
    lr: float = 0.03
    straggler_pct: float = 30.0   # s (sets τ for deadline-aware strategies)
    deadline: Optional[float] = None
    record_every: int = 10        # history record every N applied updates
    eval_every: int = 1           # eval every Nth record
    seed: int = 0
    trace: Optional[TraceConfig] = None
    # per-sample step cost (repro.fed.cost.WorkloadCostModel or scalar;
    # None = legacy): prices the derived deadline in the same units the
    # strategy's LocalTrainer.cost prices client work
    cost: Any = None


def run_federated_async(model, clients_data: List[Dict[str, np.ndarray]],
                        specs: List[ClientSpec], strategy: Strategy,
                        cfg: AsyncFLConfig,
                        aggregator: Optional[Aggregator] = None,
                        test_data: Optional[Dict] = None, init_params=None,
                        eval_batch: int = 512, scheduler=None, faults=None,
                        verbose: bool = False) -> Dict[str, Any]:
    """Drive ``strategy`` through the async event loop until
    ``cfg.max_updates`` server updates have been applied.

    ``scheduler`` (optional) is an adaptive-participation policy with the
    ``eligible_mask`` / ``observe`` / ``record_round`` protocol of
    ``repro.fed.fleet.scheduler.AdaptiveParticipation``: dispatch is
    restricted to its current cohort (FLANP doubling under asynchrony) and
    it is fed every completion's realized (work, duration) pair.

    ``faults`` (a ``repro.fed.fleet.faults`` profile, registry name, or
    None) injects seeded deterministic failures: mid-flight dropout
    discards a completion *after* its dispatch was accounted (the
    dispatch-trace cursor still advanced, so every other client's
    capability/jitter draws are unchanged), churn masks dispatch by the
    record-window present-mask, and Byzantine corruption rewrites a
    fixed client subset's updates before they reach the aggregator.

    Returns the same shape of result as ``run_federated`` plus
    ``event_log`` (list of strings) and ``telemetry`` (utilization,
    staleness histogram, makespan)."""
    # function-level import: events is imported by repro.fed before the
    # fleet subpackage exists, and fleet.__init__ imports back into here
    from repro.fed.fleet.faults import (FaultTrace, corrupt_update,
                                        get_fault_profile)
    wall0 = _time.perf_counter()
    rng = np.random.default_rng(cfg.seed)
    params = (init_params if init_params is not None
              else model.init(jax.random.PRNGKey(cfg.seed)))
    deadline = cfg.deadline
    if deadline is None:
        deadline = straggler_deadline(specs, cfg.epochs, cfg.straggler_pct,
                                      cfg.cost)
    aggregator = aggregator if aggregator is not None else FedAsync()
    aggregator.reset()
    trace = CapabilityTrace(cfg.trace) if cfg.trace is not None else None
    dispatch_limit = (cfg.max_dispatches if cfg.max_dispatches is not None
                      else 50 * cfg.max_updates + 10 * cfg.concurrency)
    eval_fn = make_eval_fn(model, test_data, eval_batch) if test_data else None

    n = len(specs)
    profile = get_fault_profile(faults)
    ftrace = (FaultTrace(profile, n, seed=cfg.seed)
              if profile is not None and profile.any_faults() else None)
    corruption = ftrace is not None and profile.has_corruption
    fault_name = profile.name if profile is not None else "none"
    sizes = np.array([s.m for s in specs], np.float64)
    busy = np.zeros(n, bool)
    busy_time = np.zeros(n)
    tracei = DispatchTraceIndexer(n, trace)
    obs = active_recorder(verbose)
    obs.run_meta(runtime="async", engine="async", strategy=strategy.name,
                 aggregator=aggregator.name, faults=fault_name, n_clients=n,
                 max_updates=cfg.max_updates, concurrency=cfg.concurrency,
                 deadline=float(deadline), seed=cfg.seed)
    # cid -> (ClientResult | None, dispatch version, dispatch-time params,
    #         realized work units)
    pending: Dict[int, Any] = {}

    queue = EventQueue()
    event_log: List[str] = []
    history: List[RoundRecord] = []
    staleness_log: List[int] = []

    version = 0
    applied = 0
    now = 0.0
    dropped_total = 0
    violations_total = 0
    # per-record accumulators
    rec_times: List[float] = []
    rec_losses: List[float] = []
    rec_rows: List[tuple] = []    # (cid, duration, dropped, violated)
    rec_coreset = 0
    rec_dropped = 0
    rec_violations = 0
    rec_start = 0.0
    rec_wall0 = _time.perf_counter()
    # the async "round" is a record-window, not a lexical block, so the
    # round span is opened/closed manually at window boundaries
    round_span = obs.span_begin("round", round=0)

    def flush_record(t: float, eval_now: bool) -> None:
        nonlocal rec_times, rec_losses, rec_rows, rec_coreset, rec_dropped
        nonlocal rec_violations, rec_applied, rec_start, rec_wall0
        nonlocal round_span
        rec = RoundRecord(
            round=len(history), sim_round_time=t - rec_start,
            client_times=rec_times, n_participants=len(rec_times),
            n_dropped=rec_dropped, n_coreset=rec_coreset,
            train_loss=(float(np.mean(rec_losses)) if rec_losses
                        else float("nan")),
            n_violations=rec_violations)
        if eval_fn and eval_now:
            with obs.span("eval", round=rec.round):
                rec.test_acc, rec.test_loss = eval_fn(params)
        if scheduler is not None:
            scheduler.record_round(rec.train_loss)
        history.append(rec)
        obs.span_end(round_span)
        obs.event("round", runtime="async", engine="async",
                  label=f"{strategy.name}/{aggregator.name}",
                  round=rec.round, n_participants=rec.n_participants,
                  n_dropped=rec_dropped, n_coreset=rec_coreset,
                  n_violations=rec_violations,
                  sim_round_time=float(rec.sim_round_time),
                  wall_time_s=_time.perf_counter() - rec_wall0,
                  train_loss=float(rec.train_loss),
                  test_acc=float(rec.test_acc),
                  test_loss=float(rec.test_loss),
                  applied=applied, t_virtual=float(t))
        obs.event("clients", round=rec.round,
                  cids=[int(c) for c, _, _, _ in rec_rows],
                  durations=[d for _, d, _, _ in rec_rows],
                  dropped=[dr for _, _, dr, _ in rec_rows],
                  violated=[v for _, _, _, v in rec_rows])
        rec_times, rec_losses, rec_rows = [], [], []
        rec_coreset = rec_dropped = rec_violations = rec_applied = 0
        rec_start = t
        rec_wall0 = _time.perf_counter()
        round_span = obs.span_begin("round", round=len(history))

    n_dispatched = 0    # push-time count — the dispatch_limit gate
    churn_logged = -1   # last record-window whose churn was counted

    def dispatch(t: float) -> bool:
        nonlocal n_dispatched, churn_logged
        if n_dispatched >= dispatch_limit:
            return False
        p = sizes * ~busy
        if scheduler is not None:
            p = p * scheduler.eligible_mask()
        if ftrace is not None and ftrace.profile.has_churn:
            # churn evolves per record-window (the async "round")
            mask, joins, leaves = ftrace.churn_step(len(history))
            p = p * mask
            if churn_logged != len(history):
                churn_logged = len(history)
                obs.metrics.counter("faults.churn_joins").inc(joins)
                obs.metrics.counter("faults.churn_leaves").inc(leaves)
                obs.metrics.gauge("faults.n_present").set(int(mask.sum()))
        total = p.sum()
        if total == 0.0:
            return False
        cid = int(rng.choice(n, p=p / total))
        busy[cid] = True
        n_dispatched += 1
        queue.push(t, DISPATCH, cid, version)
        return True

    for _ in range(min(cfg.concurrency, n)):
        dispatch(0.0)

    rec_applied = 0
    unprocessed: List[Event] = []   # events past a max_virtual_time cutoff

    while len(queue) and applied < cfg.max_updates:
        ev = queue.pop()
        if (cfg.max_virtual_time is not None
                and ev.time > cfg.max_virtual_time):
            unprocessed.append(ev)
            break
        now = ev.time
        event_log.append(ev.fmt())

        if ev.kind == DISPATCH:
            spec = specs[ev.cid]
            k = tracei.begin(ev.cid)
            if trace is not None:
                spec = dataclasses.replace(
                    spec, c=tracei.capability(spec, k))
            with obs.span("local_update", cid=ev.cid):
                res = strategy.local_update(params, clients_data[ev.cid],
                                            spec, deadline, cfg.epochs, rng)
            obs.metrics.counter("dispatches").inc()
            if res is None:     # dropped straggler: slot blocked until τ
                duration = deadline
                work = spec.c * deadline
            else:
                duration = res.sim_time
                if trace is not None:
                    duration *= tracei.jitter(spec, k)
                work = res.sim_time * spec.c
            # staleness anchors at *processing* time, when the params
            # snapshot is taken — ev.version (push time) can lag it when
            # another completion applied an update at the same timestamp
            pending[ev.cid] = (res, version, params, work, k)
            queue.push(now + duration, COMPLETE, ev.cid, version, duration)
            continue

        # COMPLETE
        res, v0, base_params, work, k_idx = pending.pop(ev.cid)
        busy[ev.cid] = False
        busy_time[ev.cid] += ev.duration
        obs.metrics.histogram("client_busy_s").observe(ev.duration)
        if scheduler is not None:
            scheduler.observe(ev.cid, work, ev.duration)
        if res is None:
            dropped_total += 1
            rec_dropped += 1
            obs.metrics.counter("drops").inc()
            rec_rows.append((ev.cid, float(ev.duration), True, False))
        elif ftrace is not None and ftrace.dropped(ev.cid, k_idx):
            # fault-injected mid-flight dropout: the client trained, the
            # update is lost.  Its dispatch was already accounted (trace
            # cursor, busy time, EWMA), so surviving clients' draws are
            # byte-identical with the fault-free run.
            dropped_total += 1
            rec_dropped += 1
            obs.metrics.counter("faults.dropped_updates").inc()
            rec_rows.append((ev.cid, float(ev.duration), True, False))
        else:
            violations_total += int(res.deadline_violated)
            rec_violations += int(res.deadline_violated)
            if res.deadline_violated:
                obs.metrics.counter("deadline_violations").inc()
            staleness = version - v0
            staleness_log.append(staleness)
            obs.metrics.histogram("staleness", exact=True).observe(staleness)
            rec_times.append(ev.duration)
            rec_losses.append(res.final_loss)
            rec_coreset += int(res.used_coreset)
            rec_rows.append((ev.cid, float(ev.duration), False,
                             bool(res.deadline_violated)))
            upd_params = res.params
            if corruption:
                # Byzantine clients rewrite their update relative to the
                # dispatch-time snapshot; honest lanes pass untouched
                upd_params, was_corrupt = corrupt_update(
                    upd_params, base_params, ev.cid, k_idx, ftrace)
                if was_corrupt:
                    obs.metrics.counter("faults.corrupted_updates").inc()
            with obs.span("aggregate", cid=ev.cid):
                new_params = aggregator.apply(
                    params, ClientUpdate(params=upd_params,
                                         n_samples=res.n_samples,
                                         staleness=staleness,
                                         base_params=base_params))
            if new_params is not None:
                params = new_params
                version += 1
                applied += 1
                rec_applied += 1
                if (applied % cfg.record_every == 0
                        or applied == cfg.max_updates):
                    flush_record(now, eval_now=(
                        len(history) % cfg.eval_every == 0
                        or applied == cfg.max_updates))
        if applied < cfg.max_updates:
            dispatch(now)

    # tail drain: a partially-filled aggregator buffer (FedBuff /
    # semi-sync) holds real completed client work — merge it rather than
    # silently dropping it at a cutoff or queue exhaustion
    if applied < cfg.max_updates:
        tail = aggregator.flush(params)
        if tail is not None:
            params = tail
            version += 1
            applied += 1
            rec_applied += 1
            obs.metrics.counter("aggregator.partial_flushes").inc()

    # partial record at a cutoff: applied-but-unrecorded updates, tail
    # drops, or contributions still sitting in an aggregator buffer
    if rec_applied or rec_times or rec_dropped:
        flush_record(now, eval_now=True)
    obs.span_end(round_span)    # the (possibly empty) trailing window

    makespan = now
    # credit clients still mid-training at termination for the busy time
    # they accrued inside [0, makespan] (their COMPLETE never processed)
    for ev in unprocessed + [e for _, _, e in queue._heap]:
        if ev.kind == COMPLETE and ev.cid in pending:
            busy_time[ev.cid] += max(0.0, ev.duration - (ev.time - makespan))
    active = tracei.counts > 0
    hist = (np.bincount(staleness_log) if staleness_log
            else np.zeros(1, np.int64))
    telemetry = {
        "makespan": float(makespan),
        "client_utilization": float(busy_time.sum()
                                    / max(n * makespan, 1e-12)),
        "active_client_utilization": float(
            busy_time[active].sum()
            / max(active.sum() * makespan, 1e-12)) if active.any() else 0.0,
        "staleness_hist": hist,
        "mean_staleness": (float(np.mean(staleness_log))
                           if staleness_log else 0.0),
        "max_staleness": int(hist.size - 1),
        "n_dispatches": int(tracei.counts.sum()),
        "n_updates_applied": applied,
        "n_dropped": dropped_total,
        "n_violations": violations_total,
        "wall_time": _time.perf_counter() - wall0,
    }
    if obs.enabled:
        obs.event("telemetry", **{k: (v.tolist() if isinstance(v, np.ndarray)
                                      else v) for k, v in telemetry.items()})
        obs.metrics.gauge("client_utilization").set(
            telemetry["client_utilization"])
        obs.metrics.gauge("active_client_utilization").set(
            telemetry["active_client_utilization"])
        obs.metrics.gauge("makespan_virtual_s").set(telemetry["makespan"])
    return {
        "params": params,
        "history": history,
        "deadline": deadline,
        "strategy": strategy.name,
        "aggregator": aggregator.name,
        "faults": fault_name,
        "version": version,
        "event_log": event_log,
        "telemetry": telemetry,
    }
