"""Federated server: round loop, client sampling, aggregation, history.

Implements Alg. 1's outer loop: sample K clients ∝ pⁱ = mⁱ/Σm with
replacement (Assumption A.6), broadcast (w_r, τ), collect local updates via
the strategy, aggregate w_{r+1} = (1/K) Σ w_rⁱ (Σ mⁱ w_rⁱ / Σ mⁱ with
``weight_by_samples=True``).  The asynchronous counterpart lives in
``repro.fed.events``; eval and history records are shared between the two.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.aggregators import SyncWeightedMean
from repro.fed.simulator import (CapabilityTrace, ClientSpec,
                                 DispatchTraceIndexer, TraceConfig,
                                 straggler_deadline)
from repro.fed.strategies import ClientResult, Strategy
from repro.obs import active_recorder


@dataclasses.dataclass
class FLConfig:
    rounds: int = 20
    clients_per_round: int = 10
    epochs: int = 10              # E
    batch_size: int = 8
    lr: float = 0.03
    straggler_pct: float = 30.0   # s
    deadline: Optional[float] = None  # τ; None => derived from straggler_pct
    eval_every: int = 1
    seed: int = 0
    # aggregate ∝ mⁱ. Default False: with clients sampled ∝ mⁱ with
    # replacement (Assumption A.6) the unbiased Alg. 1 aggregate is the
    # uniform 1/K mean — weighting by mⁱ again would double-count size.
    # True is for uniform client sampling or deliberate size weighting.
    weight_by_samples: bool = False
    # per-dispatch capability perturbations (slowdown episodes + jitter),
    # same machinery the async runtime uses — lets scenario sweeps and
    # participation schedulers see realistic durations in sync rounds too
    trace: Optional[TraceConfig] = None
    # per-sample step cost (repro.fed.cost.WorkloadCostModel or scalar;
    # None = legacy samples-cost-1.0): prices the derived deadline in the
    # same units the strategy's LocalTrainer.cost prices client work, so
    # τ means FLOPs, not raw sample counts
    cost: Any = None


@dataclasses.dataclass
class RoundRecord:
    round: int
    sim_round_time: float          # max over participating clients
    client_times: List[float]
    n_participants: int
    n_dropped: int
    n_coreset: int
    train_loss: float
    test_acc: float = float("nan")
    test_loss: float = float("nan")
    wall_time: float = 0.0
    n_violations: int = 0          # results flagged deadline_violated


def sample_clients(specs: Sequence[ClientSpec], k: int,
                   rng: np.random.Generator) -> List[int]:
    p = np.array([s.m for s in specs], np.float64)
    p /= p.sum()
    return list(rng.choice(len(specs), size=k, replace=True, p=p))


def run_federated(model, clients_data: List[Dict[str, np.ndarray]],
                  specs: List[ClientSpec], strategy: Strategy,
                  cfg: FLConfig, test_data: Optional[Dict] = None,
                  init_params=None, eval_batch: int = 512,
                  scheduler=None, aggregator: str = "weighted_mean",
                  faults=None, verbose: bool = False) -> Dict[str, Any]:
    """Synchronous Alg. 1 round loop.

    ``scheduler`` (optional) is an adaptive-participation policy with the
    ``select`` / ``observe`` / ``record_round`` protocol of
    ``repro.fed.fleet.scheduler.AdaptiveParticipation`` (duck-typed to
    avoid an import cycle): it replaces ∝ mⁱ sampling with its own cohort
    and is fed realized durations, so FLANP-style doubling cohorts work on
    the sync server too.  ``cfg.trace`` perturbs each dispatch's
    capability exactly as the async runtime does.

    ``aggregator`` selects the round merge: ``"weighted_mean"`` (Alg. 1)
    or any robust estimator from ``repro.fed.aggregators.ROBUST_METHODS``
    (trimmed_mean / median / krum / multi_krum / norm_clip).  ``faults``
    (a ``repro.fed.fleet.faults`` profile or name) injects seeded
    dropout / churn / Byzantine corruption without perturbing surviving
    clients' capability draws.
    """
    from repro.fed.aggregators import ROBUST_METHODS, robust_combine, \
        stack_params
    from repro.fed.fleet.faults import (FaultTrace, corrupt_update,
                                        get_fault_profile)
    if aggregator != "weighted_mean" and aggregator not in ROBUST_METHODS:
        raise ValueError(
            f"unknown sync aggregator {aggregator!r} (expected "
            f"'weighted_mean' or one of {sorted(ROBUST_METHODS)})")
    rng = np.random.default_rng(cfg.seed)
    params = (init_params if init_params is not None
              else model.init(jax.random.PRNGKey(cfg.seed)))
    deadline = cfg.deadline
    if deadline is None:
        deadline = straggler_deadline(specs, cfg.epochs, cfg.straggler_pct,
                                      cfg.cost)

    history: List[RoundRecord] = []
    eval_fn = make_eval_fn(model, test_data, eval_batch) if test_data else None
    mean_agg = SyncWeightedMean(cfg.weight_by_samples)
    trace = CapabilityTrace(cfg.trace) if cfg.trace is not None else None
    tracei = DispatchTraceIndexer(len(specs), trace)
    profile = get_fault_profile(faults)
    ftrace = (FaultTrace(profile, len(specs), seed=cfg.seed)
              if profile is not None and profile.any_faults() else None)
    fault_name = profile.name if profile is not None else "none"
    obs = active_recorder(verbose)
    obs.run_meta(runtime="sync", engine="sync", strategy=strategy.name,
                 aggregator=aggregator, faults=fault_name,
                 n_clients=len(specs), rounds=cfg.rounds,
                 deadline=float(deadline), seed=cfg.seed)

    for r in range(cfg.rounds):
        t0 = time.perf_counter()
        rspan = obs.span_begin("round", round=r)
        with obs.span("cohort_select", round=r):
            if scheduler is not None:
                selected = [int(c) for c in scheduler.select()]
            else:
                selected = sample_clients(specs, cfg.clients_per_round, rng)
            if ftrace is not None and ftrace.profile.has_churn:
                # churned-out clients silently miss the round; the
                # sampling draw above already happened, so survivors'
                # RNG streams match the churn-free run
                mask, joins, leaves = ftrace.churn_step(r)
                selected = [c for c in selected if mask[c]]
                obs.metrics.counter("faults.churn_joins").inc(joins)
                obs.metrics.counter("faults.churn_leaves").inc(leaves)
                obs.metrics.gauge("faults.n_present").set(int(mask.sum()))
        results: List[ClientResult] = []
        times: List[float] = []
        drop_times: List[float] = []
        dropped = 0
        n_corrupted = 0
        client_rows = []    # (cid, sim duration, dropped, violated)
        with obs.span("local_update", round=r):
            for cid in selected:
                spec = specs[cid]
                k = tracei.begin(cid)
                if trace is not None:
                    spec = dataclasses.replace(spec,
                                               c=tracei.capability(spec, k))
                res = strategy.local_update(params, clients_data[cid], spec,
                                            deadline, cfg.epochs, rng)
                obs.metrics.counter("dispatches").inc()
                if res is None:
                    dropped += 1
                    obs.metrics.counter("drops").inc()
                    client_rows.append((cid, float(deadline), True, False))
                    # dropped stragglers in FedAvg-DS still busy until τ
                    drop_times.append(float(deadline))
                    if scheduler is not None:   # a drop still occupies τ
                        scheduler.observe(cid, spec.c * deadline, deadline)
                else:
                    duration = res.sim_time
                    if trace is not None:
                        duration *= tracei.jitter(spec, k)
                    if scheduler is not None:
                        scheduler.observe(cid, res.sim_time * spec.c,
                                          duration)
                    if ftrace is not None and ftrace.dropped(cid, k):
                        # fault dropout: the client trained (its trace
                        # cursor advanced, the round waits for it) but
                        # the update never reaches the server
                        dropped += 1
                        obs.metrics.counter("faults.dropped_updates").inc()
                        client_rows.append((cid, float(duration), True,
                                            False))
                        drop_times.append(float(duration))
                        continue
                    if ftrace is not None and ftrace.profile.has_corruption:
                        cp, was_c = corrupt_update(res.params, params,
                                                   cid, k, ftrace)
                        if was_c:
                            n_corrupted += 1
                            obs.metrics.counter(
                                "faults.corrupted_updates").inc()
                            res = dataclasses.replace(res, params=cp)
                    results.append(res)
                    times.append(duration)
                    obs.metrics.histogram("client_busy_s").observe(duration)
                    if res.deadline_violated:
                        obs.metrics.counter("deadline_violations").inc()
                    client_rows.append((cid, float(duration), False,
                                        bool(res.deadline_violated)))

        with obs.span("aggregate", round=r):
            if results:
                if aggregator == "weighted_mean":
                    params = mean_agg.aggregate(
                        [r_.params for r_ in results],
                        [r_.n_samples for r_ in results],
                        fallback=params)
                else:
                    weights = ([r_.n_samples for r_ in results]
                               if cfg.weight_by_samples else None)
                    params = robust_combine(
                        stack_params([r_.params for r_ in results]),
                        aggregator, weights=weights, base=params)
        round_time = max(times + drop_times + [0.0])
        train_loss = float(np.mean([r_.final_loss for r_ in results])
                           ) if results else float("nan")
        if scheduler is not None:
            scheduler.record_round(train_loss)
        rec = RoundRecord(
            round=r, sim_round_time=round_time, client_times=times,
            n_participants=len(results), n_dropped=dropped,
            n_coreset=sum(r_.used_coreset for r_ in results),
            train_loss=train_loss, wall_time=time.perf_counter() - t0,
            n_violations=sum(r_.deadline_violated for r_ in results))
        if eval_fn and (r % cfg.eval_every == 0 or r == cfg.rounds - 1):
            with obs.span("eval", round=r):
                rec.test_acc, rec.test_loss = eval_fn(params)
        history.append(rec)
        obs.span_end(rspan)
        obs.event("round", runtime="sync", engine="sync",
                  label=strategy.name, round=r,
                  n_participants=rec.n_participants, n_dropped=dropped,
                  n_corrupted=n_corrupted,
                  n_coreset=rec.n_coreset, n_violations=rec.n_violations,
                  sim_round_time=float(round_time),
                  wall_time_s=time.perf_counter() - t0,
                  train_loss=float(train_loss),
                  test_acc=float(rec.test_acc),
                  test_loss=float(rec.test_loss))
        obs.event("clients", round=r,
                  cids=[int(c) for c, _, _, _ in client_rows],
                  durations=[d for _, d, _, _ in client_rows],
                  dropped=[dr for _, _, dr, _ in client_rows],
                  violated=[v for _, _, _, v in client_rows])

    return {
        "params": params,
        "history": history,
        "deadline": deadline,
        "strategy": strategy.name,
        "aggregator": aggregator,
        "faults": fault_name,
    }


def make_eval_fn(model, test_data, eval_batch: int):
    """Batched test-set (accuracy, loss) closure shared by sync and async."""
    @jax.jit
    def _acc(params, batch):
        return model.accuracy(params, batch), model.loss(params, batch)[0]

    def eval_fn(params):
        m = len(next(iter(test_data.values())))
        accs, losses, ns = [], [], []
        for lo in range(0, m, eval_batch):
            batch = {k: jnp.asarray(v[lo:lo + eval_batch])
                     for k, v in test_data.items()}
            a, l = _acc(params, batch)
            n = len(next(iter(batch.values())))
            accs.append(float(a) * n)
            losses.append(float(l) * n)
            ns.append(n)
        return sum(accs) / sum(ns), sum(losses) / sum(ns)

    return eval_fn


def summarize(history: List[RoundRecord], deadline: float) -> Dict[str, float]:
    if not history:     # e.g. async run cut off before its first record
        return {k: float("nan") for k in (
            "mean_round_time", "mean_round_time_normalized",
            "max_round_time_normalized", "final_test_acc", "best_test_acc",
            "final_train_loss")}
    times = np.array([h.sim_round_time for h in history])
    accs = np.array([h.test_acc for h in history])
    accs = accs[~np.isnan(accs)]
    return {
        "mean_round_time": float(times.mean()),
        "mean_round_time_normalized": float(times.mean() / deadline),
        "max_round_time_normalized": float(times.max() / deadline),
        "final_test_acc": float(accs[-1]) if len(accs) else float("nan"),
        "best_test_acc": float(accs.max()) if len(accs) else float("nan"),
        "final_train_loss": float(history[-1].train_loss),
    }
