"""npz-based pytree checkpointing (flat key-path encoding, no extra deps).

Round-resumable server state = {params, round, rng_state} saved atomically
(write temp + rename) so an interrupted run never corrupts the latest file.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree) -> None:
    flat = _flatten(tree)
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_pytree(path: str, like=None):
    """Load a pytree.  If `like` is given, restore its exact structure."""
    with np.load(path, allow_pickle=False) as data:
        flat = {k: data[k] for k in data.files if k != "__treedef__"}
    if like is not None:
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)
        paths = [_SEP.join(_path_str(p) for p in path)
                 for path, _ in leaves_with_paths[0]]
        leaves = [jnp.asarray(flat[p]) for p in paths]
        return jax.tree_util.tree_unflatten(leaves_with_paths[1], leaves)
    # otherwise reconstruct nested dicts from the path encoding
    out: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)
    return out


def latest_checkpoint(directory: str, prefix: str = "ckpt_"
                      ) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = re.fullmatch(rf"{re.escape(prefix)}(\d+)\.npz", name)
        if m and int(m.group(1)) > best_step:
            best_step = int(m.group(1))
            best = os.path.join(directory, name)
    return best


def save_server_state(directory: str, round_idx: int, params,
                      extra: Optional[Dict[str, Any]] = None,
                      prefix: str = "ckpt_") -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{prefix}{round_idx:06d}.npz")
    save_pytree(path, params)
    meta = {"round": round_idx, **(extra or {})}
    with open(os.path.join(directory, f"{prefix}{round_idx:06d}.json"),
              "w") as f:
        json.dump(meta, f)
    return path


def load_server_state(directory: str, like=None, prefix: str = "ckpt_"
                      ) -> Tuple[Optional[Any], int]:
    path = latest_checkpoint(directory, prefix)
    if path is None:
        return None, -1
    params = load_pytree(path, like)
    meta_path = path.replace(".npz", ".json")
    round_idx = -1
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            round_idx = json.load(f).get("round", -1)
    return params, round_idx
