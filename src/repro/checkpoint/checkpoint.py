"""npz-based pytree checkpointing (flat key-path encoding, no extra deps).

Round-resumable server state = {params, round, rng_state} saved atomically
(write temp + rename) so an interrupted run never corrupts the latest file.

``save_pytree`` stores a JSON structure descriptor under the reserved
``__treedef__`` key alongside the arrays, so ``load_pytree`` without a
``like`` template round-trips the exact container structure (dict / list
/ tuple / None) *and* leaf dtypes — including int64/float64 leaves that
``jnp.asarray`` would silently downcast when x64 is disabled.  Trees
with exotic pytree nodes (namedtuples, custom registrations) or
non-string dict keys fall back to the legacy nested-dict reconstruction
and still load exactly with ``like``.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
_TREEDEF_KEY = "__treedef__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _treedef_desc(tree) -> Optional[Dict[str, Any]]:
    """JSON-able structure descriptor, or None when the tree contains a
    node the path encoding cannot round-trip (then callers must pass
    ``like`` at load time, as before)."""
    if tree is None:
        return {"kind": "none"}
    if isinstance(tree, dict):
        keys = list(tree.keys())
        if any(not isinstance(k, str) or _SEP in k or k.startswith("#")
               for k in keys):
            return None
        children = {}
        for k in keys:
            d = _treedef_desc(tree[k])
            if d is None:
                return None
            children[k] = d
        return {"kind": "dict", "children": children}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return None  # namedtuple: plain-tuple rebuild would change type
    if isinstance(tree, (list, tuple)):
        children = []
        for v in tree:
            d = _treedef_desc(v)
            if d is None:
                return None
            children.append(d)
        return {"kind": "list" if isinstance(tree, list) else "tuple",
                "children": children}
    return {"kind": "leaf"}


def _rebuild(desc: Dict[str, Any], flat: Dict[str, np.ndarray],
             prefix: str):
    kind = desc["kind"]
    if kind == "none":
        return None
    if kind == "leaf":
        val = flat[prefix]
        arr = jnp.asarray(val)
        # x64-disabled jax downcasts int64/float64 — keep the exact
        # saved dtype as a numpy leaf instead of silently truncating
        return val if arr.dtype != val.dtype else arr
    join = (lambda part: part if not prefix else f"{prefix}{_SEP}{part}")
    if kind == "dict":
        return {k: _rebuild(d, flat, join(k))
                for k, d in desc["children"].items()}
    seq = [_rebuild(d, flat, join(f"#{i}"))
           for i, d in enumerate(desc["children"])]
    return seq if kind == "list" else tuple(seq)


def save_pytree(path: str, tree) -> None:
    flat = _flatten(tree)
    desc = _treedef_desc(tree)
    if desc is not None:
        flat[_TREEDEF_KEY] = np.array(json.dumps(desc))
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_pytree(path: str, like=None):
    """Load a pytree.  If `like` is given, restore its exact structure;
    otherwise rebuild from the saved ``__treedef__`` descriptor (exact
    containers + dtypes), falling back to nested dicts for legacy files."""
    with np.load(path, allow_pickle=False) as data:
        flat = {k: data[k] for k in data.files if k != _TREEDEF_KEY}
        desc_raw = (str(data[_TREEDEF_KEY])
                    if _TREEDEF_KEY in data.files else None)
    if like is not None:
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)
        paths = [_SEP.join(_path_str(p) for p in path)
                 for path, _ in leaves_with_paths[0]]
        leaves = [jnp.asarray(flat[p]) for p in paths]
        return jax.tree_util.tree_unflatten(leaves_with_paths[1], leaves)
    if desc_raw is not None:
        return _rebuild(json.loads(desc_raw), flat, "")
    # legacy files: reconstruct nested dicts from the path encoding
    out: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)
    return out


def _readable_npz(path: str) -> bool:
    try:
        with np.load(path, allow_pickle=False) as data:
            data.files
        return True
    except Exception:
        return False


def latest_checkpoint(directory: str, prefix: str = "ckpt_"
                      ) -> Optional[str]:
    """Newest *complete* checkpoint: partially-written or corrupt npz
    files (e.g. a crash mid-copy onto the target name) are skipped so a
    resume never trips over a torn file."""
    if not os.path.isdir(directory):
        return None
    candidates = []
    for name in os.listdir(directory):
        m = re.fullmatch(rf"{re.escape(prefix)}(\d+)\.npz", name)
        if m:
            candidates.append((int(m.group(1)), os.path.join(directory, name)))
    for _, path in sorted(candidates, reverse=True):
        if _readable_npz(path):
            return path
    return None


def save_server_state(directory: str, round_idx: int, params,
                      extra: Optional[Dict[str, Any]] = None,
                      prefix: str = "ckpt_") -> str:
    """Atomic {params npz + JSON meta} pair.  The meta sidecar is written
    (atomically) *before* the npz is renamed into place, so a complete
    npz always has its meta — a crash in between leaves only an orphan
    json that ``latest_checkpoint`` never selects."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{prefix}{round_idx:06d}.npz")
    meta_path = os.path.join(directory, f"{prefix}{round_idx:06d}.json")
    meta = {"round": round_idx, **(extra or {})}
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, meta_path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    save_pytree(path, params)
    return path


def load_server_state(directory: str, like=None, prefix: str = "ckpt_"
                      ) -> Tuple[Optional[Any], int]:
    path = latest_checkpoint(directory, prefix)
    if path is None:
        return None, -1
    params = load_pytree(path, like)
    meta_path = path[:-len(".npz")] + ".json"
    round_idx = -1
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            round_idx = json.load(f).get("round", -1)
    return params, round_idx


def load_server_meta(directory: str, prefix: str = "ckpt_"
                     ) -> Optional[Dict[str, Any]]:
    """Full JSON meta dict of the latest complete checkpoint (the
    ``extra`` payload runtimes stash scheduler/RNG/event-loop state in),
    or None when there is no checkpoint or no meta sidecar."""
    path = latest_checkpoint(directory, prefix)
    if path is None:
        return None
    meta_path = path[:-len(".npz")] + ".json"
    if not os.path.exists(meta_path):
        return None
    with open(meta_path) as f:
        return json.load(f)
