from repro.checkpoint.checkpoint import (  # noqa: F401
    load_pytree,
    save_pytree,
    latest_checkpoint,
    save_server_state,
    load_server_state,
    load_server_meta,
)
