from repro.data.synthetic import synthetic_dataset  # noqa: F401
from repro.data.mnist_like import mnist_like_dataset  # noqa: F401
from repro.data.charlm import shakespeare_like_dataset  # noqa: F401
from repro.data.partition import power_law_sizes, train_test_split_clients  # noqa: F401
from repro.data.batching import batch_iterator, epoch_batches  # noqa: F401
