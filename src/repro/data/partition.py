"""Client partitioning helpers."""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def power_law_sizes(n_clients: int, mean: float, std: float,
                    rng: np.random.Generator, min_size: int = 8) -> np.ndarray:
    """Lognormal client sizes matched to a target mean/std (paper Table 1)."""
    mu = np.log(mean**2 / np.sqrt(std**2 + mean**2))
    sigma = np.sqrt(np.log(1 + std**2 / mean**2))
    sizes = rng.lognormal(mu, sigma, n_clients)
    return np.maximum(sizes.astype(int), min_size)


def train_test_split_clients(clients: List[Dict[str, np.ndarray]],
                             test_frac: float = 0.1,
                             rng: np.random.Generator | None = None
                             ) -> Tuple[list, dict]:
    """Hold out `test_frac` of every client's data into one global test set."""
    rng = rng or np.random.default_rng(0)
    train, test_parts = [], []
    for data in clients:
        m = len(next(iter(data.values())))
        n_test = max(1, int(m * test_frac))
        perm = rng.permutation(m)
        te, tr = perm[:n_test], perm[n_test:]
        train.append({k: v[tr] for k, v in data.items()})
        test_parts.append({k: v[te] for k, v in data.items()})
    test = {k: np.concatenate([p[k] for p in test_parts])
            for k in test_parts[0]}
    return train, test
