"""Shakespeare-style federated char-LM surrogate (Table 1: 143 clients =
speaking roles, mean 3616 samples/client, next-character prediction).

Text is drawn from a shared order-1 character Markov chain (English-like
bigram statistics synthesized from a seeded random sparse transition matrix)
with a per-client "style" perturbation of the transition probabilities —
giving the cross-client statistical heterogeneity of per-role text without
shipping the corpus.  Samples are (seq, next-seq) windows exactly like the
LEAF Shakespeare task.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.partition import power_law_sizes

VOCAB = 64  # reduced printable charset


def _base_chain(rng: np.random.Generator, vocab: int) -> np.ndarray:
    """Sparse-ish bigram transition matrix with Zipfian character marginals."""
    marg = 1.0 / np.arange(1, vocab + 1) ** 1.1
    marg /= marg.sum()
    T = rng.gamma(0.3, size=(vocab, vocab)) * marg[None, :]
    T /= T.sum(axis=1, keepdims=True)
    return T


def shakespeare_like_dataset(n_clients: int = 143, mean_samples: float = 3616.0,
                             std_samples: float = 6808.0, seq_len: int = 80,
                             style_temp: float = 0.4, seed: int = 0
                             ) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    T = _base_chain(rng, VOCAB)
    sizes = power_law_sizes(n_clients, mean_samples, std_samples, rng,
                            min_size=32)
    clients = []
    for i in range(n_clients):
        style = rng.gamma(1.0 / max(style_temp, 1e-3),
                          size=(VOCAB, VOCAB)) * style_temp
        Ti = T * style
        Ti /= Ti.sum(axis=1, keepdims=True)
        m = int(sizes[i])
        # one long stream, then windowed
        n_chars = m + seq_len + 1
        cum = np.cumsum(Ti, axis=1)
        chars = np.empty(n_chars, np.int32)
        chars[0] = rng.integers(VOCAB)
        u = rng.random(n_chars)
        for t in range(1, n_chars):
            chars[t] = np.searchsorted(cum[chars[t - 1]], u[t])
        x = np.lib.stride_tricks.sliding_window_view(
            chars[:-1], seq_len)[:m].copy()
        y = np.lib.stride_tricks.sliding_window_view(
            chars[1:], seq_len)[:m].copy()
        clients.append({"x": x.astype(np.int32), "y": y.astype(np.int32)})
    return clients
