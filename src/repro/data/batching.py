"""Mini-batch iteration over client datasets (numpy-side, feeding jit steps)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def epoch_batches(data: Dict[str, np.ndarray], batch_size: int,
                  rng: np.random.Generator, drop_remainder: bool = False
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Shuffled mini-batches for one epoch.

    Per-example ``weights`` (coreset δ) ride along with their samples.
    """
    m = len(next(iter(data.values())))
    perm = rng.permutation(m)
    end = (m // batch_size) * batch_size if drop_remainder else m
    for lo in range(0, end, batch_size):
        idx = perm[lo:lo + batch_size]
        yield {k: v[idx] for k, v in data.items()}


def batch_iterator(data: Dict[str, np.ndarray], batch_size: int, steps: int,
                   rng: np.random.Generator) -> Iterator[Dict[str, np.ndarray]]:
    """Endless shuffled batches, stopping after `steps` batches."""
    done = 0
    while done < steps:
        for batch in epoch_batches(data, batch_size, rng):
            yield batch
            done += 1
            if done >= steps:
                return
