"""Synthetic(α, β) federated dataset — exact re-implementation of the
generator from FedProx (Li et al., 2020), used by the paper's Synthetic
benchmark (§6.1): α controls cross-client model heterogeneity, β controls
within-client feature heterogeneity.

Per client i:
    u_i ~ N(0, α);     W_i ~ N(u_i, 1) ∈ R^{60×10},  b_i ~ N(u_i, 1) ∈ R^10
    B_i ~ N(0, β);     v_i ~ N(B_i, 1) ∈ R^60
    x_ij ~ N(v_i, Σ),  Σ = diag(j^{-1.2})
    y_ij = argmax(softmax(W_i x_ij + b_i))
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.partition import power_law_sizes


def synthetic_dataset(alpha: float, beta: float, n_clients: int = 30,
                      n_features: int = 60, n_classes: int = 10,
                      mean_samples: float = 670.0, std_samples: float = 1148.0,
                      seed: int = 0) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    sizes = power_law_sizes(n_clients, mean_samples, std_samples, rng,
                            min_size=20)
    diag = np.array([(j + 1) ** (-1.2) for j in range(n_features)])
    clients = []
    for i in range(n_clients):
        u = rng.normal(0.0, np.sqrt(alpha))
        Bm = rng.normal(0.0, np.sqrt(beta))
        W = rng.normal(u, 1.0, (n_features, n_classes))
        b = rng.normal(u, 1.0, n_classes)
        v = rng.normal(Bm, 1.0, n_features)
        m = int(sizes[i])
        x = rng.normal(loc=v, scale=np.sqrt(diag), size=(m, n_features))
        logits = x @ W + b
        y = np.argmax(logits, axis=1)
        clients.append({"x": x.astype(np.float32), "y": y.astype(np.int32)})
    return clients
