"""Pseudo-MNIST: an offline, distribution-matched surrogate for the paper's
MNIST benchmark (Table 1: 1000 clients, ~69 samples/client mean, 106 std,
2 distinct digits per client, power-law sizes).

Images are generated from 10 smooth random class prototypes (low-frequency
Gaussian fields) plus per-sample elastic-ish jitter and pixel noise — a task
a small CNN learns to >95% but that is not linearly separable, preserving
the benchmark's role.  Documented as a surrogate in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.data.partition import power_law_sizes


def _smooth_field(rng: np.random.Generator, size: int, cutoff: int = 6
                  ) -> np.ndarray:
    """Low-frequency random image in [-1, 1]."""
    spec = np.zeros((size, size), np.complex128)
    spec[:cutoff, :cutoff] = (rng.normal(size=(cutoff, cutoff))
                              + 1j * rng.normal(size=(cutoff, cutoff)))
    img = np.real(np.fft.ifft2(spec))
    img = img / (np.abs(img).max() + 1e-9)
    return img


def make_prototypes(n_classes: int = 10, size: int = 28, seed: int = 1234
                    ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([_smooth_field(rng, size) for _ in range(n_classes)])


def mnist_like_dataset(n_clients: int = 1000, mean_samples: float = 69.0,
                       std_samples: float = 106.0, digits_per_client: int = 2,
                       n_classes: int = 10, size: int = 28,
                       noise: float = 0.35, seed: int = 0
                       ) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    protos = make_prototypes(n_classes, size)
    sizes = power_law_sizes(n_clients, mean_samples, std_samples, rng,
                            min_size=10)
    clients = []
    for i in range(n_clients):
        digits = rng.choice(n_classes, size=digits_per_client, replace=False)
        m = int(sizes[i])
        y = rng.choice(digits, size=m)
        shift = rng.integers(-2, 3, size=(m, 2))
        xs = np.empty((m, size, size), np.float32)
        for j in range(m):
            img = np.roll(protos[y[j]], tuple(shift[j]), axis=(0, 1))
            xs[j] = img + noise * rng.normal(size=(size, size))
        clients.append({"x": xs.astype(np.float32), "y": y.astype(np.int32)})
    return clients
