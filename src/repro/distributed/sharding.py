"""Sharding rules: map every param / input / decode-state leaf to a
PartitionSpec for the production mesh.

Baseline layout ("tp"): tensor parallelism over the ``model`` axis, pure
data parallelism over ``pod``x``data`` (params replicated there).  The
"fsdp" mode additionally shards the params' other large dim over ``data``
(ZeRO-3 style) — one of the beyond-paper perf iterations.

Rules are matched on the flattened key path of each leaf, most-specific
first; anything unmatched is replicated.  All rules respect divisibility:
a dim is only sharded if its size divides the axis size (otherwise the
leaf silently falls back to replication on that dim — important for GQA
caches with kv_heads < model-axis size).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis assignments whose size doesn't divide the dim."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(spec))):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (path regex, spec builder); specs are written for the *unstacked* trailing
# dims — stacked layer params get a leading None automatically by _fit
# (the L dim never divides evenly and is never sharded).
def _param_rules(cfg: ModelConfig, fsdp: bool):
    d_ax = "data" if fsdp else None  # ZeRO dim
    return [
        # embeddings / unembedding: vocab over model
        (r"\bembed\b$", lambda s: P("model", d_ax)),
        (r"\bw_unembed\b$", lambda s: P(d_ax, "model")),
        # attention
        (r"attn.*\bwq\b$|attn.*\bwk\b$|attn.*\bwv\b$|xattn.*\bw[qkv]\b$",
         lambda s: P(d_ax, "model")),
        (r"attn.*\bwo\b$|xattn.*\bwo\b$", lambda s: P("model", d_ax)),
        # dense mlp
        (r"mlp.*\bw_gate\b$|mlp.*\bw_up\b$|shared.*\bw_gate\b$|"
         r"shared.*\bw_up\b$", lambda s: P(d_ax, "model")),
        (r"mlp.*\bw_down\b$|shared.*\bw_down\b$", lambda s: P("model", d_ax)),
        # MoE: experts over model (expert parallelism)
        (r"moe.*\bw_gate\b$|moe.*\bw_up\b$", lambda s: P("model", d_ax,
                                                         None)),
        (r"moe.*\bw_down\b$", lambda s: P("model", None, d_ax)),
        (r"moe.*\brouter\b$", lambda s: P(d_ax, None)),
        # mamba2: inner projections sharded on the wide dim
        (r"\bw_in\b$", lambda s: P(d_ax, "model")),
        (r"\bw_out\b$", lambda s: P("model", d_ax)),
        (r"\bconv_w\b$", lambda s: P(None, "model")),
        (r"\bconv_b\b$", lambda s: P("model")),
        # zamba shared concat projection
        (r"\bshared_in\b$", lambda s: P(d_ax, "model")),
        # xlstm
        (r"\bwq\b$|\bwk\b$|\bwv\b$|\bwo_gate\b$", lambda s: P(d_ax, "model")),
        (r"\br\b$", lambda s: P(None, "model", None, None)),
    ]


def param_specs(cfg: ModelConfig, params_shapes, mesh: Mesh,
                mode: str = "tp"):
    """params_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    rules = _param_rules(cfg, fsdp=(mode == "fsdp"))

    flat = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat[0]:
        key = "/".join(_p(p) for p in path)
        spec = P()
        for pattern, builder in rules:
            if re.search(pattern, key):
                raw = builder(leaf.shape)
                # stacked-layer params: shift spec right past the L dim
                if _is_stacked(key, leaf.shape, raw):
                    raw = P(None, *tuple(raw))
                spec = _fit(raw, leaf.shape, mesh)
                break
        specs.append(spec)
    return jax.tree_util.tree_unflatten(flat[1], specs)


def _is_stacked(key: str, shape, raw: P) -> bool:
    """Heuristic: stacked layer params carry a leading L dim."""
    return ("layers" in key and len(shape) == len(tuple(raw)) + 1)


def _p(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


# ---------------------------------------------------------------------------
# batch / decode-state rules
# ---------------------------------------------------------------------------

def shard_batch_axes(mesh: Mesh) -> tuple:
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


def batch_specs(batch_shapes, mesh: Mesh):
    """Shard the leading batch dim over (pod, data) when divisible."""
    axes = shard_batch_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        return _fit(P(axes), leaf.shape, mesh)

    return jax.tree.map(one, batch_shapes)


def decode_state_specs(cfg: ModelConfig, state_shapes, mesh: Mesh,
                       context_parallel: bool = False):
    """KV caches: batch over (pod, data); kv-heads over model when they
    divide; with ``context_parallel=True`` the cache *sequence* dim is
    sharded over model instead (for GQA archs whose kv_heads < |model|) —
    a beyond-paper perf option exercised in §Perf.
    """
    axes = shard_batch_axes(mesh)

    def one(path, leaf):
        key = "/".join(_p(p) for p in path)
        shape = leaf.shape
        if "kv" in key and leaf.ndim == 5:      # (L, B, S, Hk, hd)
            if context_parallel:
                spec = P(None, axes, "model", None, None)
            else:
                spec = P(None, axes, None, "model", None)
            return _fit(spec, shape, mesh)
        if "enc_" in key and leaf.ndim == 4:    # (L, B, S_enc, Hk, hd)? 4/5d
            return _fit(P(None, axes, None, None, None), shape, mesh)
        if "mamba" in key and leaf.ndim >= 3:   # (L, B, nh, hd, n) / conv
            if leaf.ndim == 5:
                return _fit(P(None, axes, "model", None, None), shape, mesh)
            return _fit(P(None, axes, None, "model"), shape, mesh)
        if leaf.ndim >= 2:                      # xlstm block states (B, H,..)
            return _fit(P(axes, "model"), shape, mesh)
        return _fit(P(axes), shape, mesh)

    flat = jax.tree_util.tree_flatten_with_path(state_shapes)
    specs = [one(path, leaf) for path, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)
