"""Cross-silo FedAvg as mesh collectives.

In the cross-silo regime (DESIGN.md §5), FL clients are silos living on
mesh rows: silo i's local params occupy the `data` (and `pod`) slices of
the mesh.  Server aggregation w <- (Σ wᵢ·mᵢ)/(Σ mᵢ) is then not an RPC but
a **weighted psum over the client axes** via shard_map — on hardware this
lowers to one all-reduce over ICI within a pod plus one over DCN across
pods (hierarchical FedAvg for free from mesh factorization).

Layout contract: every leaf of ``local_params`` carries a leading silo dim
of size n_silos = Π|client_axes|, sharded over ``client_axes``; ``weights``
is (n_silos,) sharded the same way.  The output drops the silo dim and is
replicated — ready to broadcast into the next round.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def weighted_psum_sum(weights, stacked, axes: Tuple[str, ...]):
    """Weighted sum over a sharded leading client dim, reduced with psum.

    Must be called *inside* a ``shard_map`` body.  ``stacked`` is a pytree
    whose leaves carry a leading local-client dim matching ``weights``
    (local_clients,); the weighted sum over that dim is reduced locally and
    then psum'd over the mesh ``axes`` — on hardware a tree all-reduce over
    ICI/DCN.  Returns ``(summed pytree with the client dim removed,
    total weight)``, both replicated across ``axes``.  Shared by the
    cross-silo FedAvg collective below and the sharded fleet engine
    (``repro.fed.fleet.sharded``), so both aggregate with the same
    order-stable device-resident reduction.
    """
    total_w = jax.lax.psum(jnp.sum(weights), axes)

    def one(leaf):
        wl = weights.astype(leaf.dtype).reshape(
            (-1,) + (1,) * (leaf.ndim - 1))
        return jax.lax.psum(jnp.sum(leaf * wl, axis=0), axes)

    return jax.tree.map(one, stacked), total_w


def fedavg_allreduce(local_params, weights, mesh: Mesh,
                     client_axes: Tuple[str, ...] = ("pod", "data")):
    """Weighted FedAvg across the client mesh axes.

    local_params: pytree; each leaf (n_silos, ...) sharded P(client_axes).
    weights: (n_silos,) aggregation weights (mⁱ, or ones for uniform 1/K).
    Returns the aggregated pytree with the silo dim removed, replicated.
    """
    axes = tuple(a for a in client_axes if a in mesh.axis_names)

    def agg(w, *leaves):
        # each shard sees (silos_per_shard, ...); reduce locally then psum
        summed, total_w = weighted_psum_sum(w, list(leaves), axes)
        return tuple(leaf / total_w for leaf in summed)

    flat, treedef = jax.tree.flatten(local_params)
    in_specs = (P(axes),) + tuple(
        P(*((axes,) + (None,) * (leaf.ndim - 1))) for leaf in flat)
    out_specs = tuple(P(*((None,) * (leaf.ndim - 1))) for leaf in flat)
    fn = shard_map(agg, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    out = fn(weights, *flat)
    return jax.tree.unflatten(treedef, list(out))
