from repro.distributed.sharding import (  # noqa: F401
    batch_specs,
    decode_state_specs,
    param_specs,
    shard_batch_axes,
)
from repro.distributed.fedavg_mesh import (  # noqa: F401
    fedavg_allreduce,
    weighted_psum_sum,
)
